"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch × shape × mesh), all in seconds per step, TPU v5e:

  compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak per chip)
  memory     = HLO_bytes_per_device / 819e9         (HBM bw per chip)
  collective = collective_operand_bytes / 50e9      (per-link ICI bw)

cost_analysis() reports the per-device SPMD program, so terms are already
per-chip.  MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active
params for MoE; the ratio MODEL_FLOPS/(HLO_FLOPs·devices) exposes remat and
redundant-compute waste (it exceeds ~1/3 only if remat is free, so ~0.25-0.5
is healthy for remat'd training).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "out", "dryrun")

_PARAM_CACHE = {}


def param_counts(arch: str):
    """(total, active) parameter counts via abstract init (no allocation)."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    cfg = get_config(arch)
    mesh = make_local_mesh()
    model = build_model(cfg, mesh)
    shapes = jax.eval_shape(lambda k: model.init(k)[0], jax.random.key(0))
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.n_experts and cfg.n_experts in leaf.shape:
            n = n * cfg.experts_per_token // cfg.n_experts
        active += n
    _PARAM_CACHE[arch] = (total, active)
    return total, active


def scan_trips(rec: dict) -> int:
    """XLA:CPU cost_analysis counts while/scan bodies ONCE; the layer stack
    runs n_periods times (× microbatches for train).  We scale flops/bytes/
    collective-bytes by this static trip count — it overcounts the
    outside-of-scan prologue (embed/logits/optimizer), so treat the terms
    as upper-bound estimates good for dominant-term identification (the
    per-cell JSON keeps the raw uncorrected numbers)."""
    from repro.configs import get_config, get_shape
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    trips = max(cfg.n_layers // max(len(cfg.block_pattern), 1), 1)
    if shape.kind == "train":
        trips *= max(cfg.microbatches, 1)
    return trips


def analyze(rec: dict) -> dict:
    from repro.configs import get_shape
    shape = get_shape(rec["shape"])
    trips = scan_trips(rec)
    flops = rec["flops_per_device"] * trips
    # bytes: the parameter/optimizer streams run once per step, not per
    # scan trip — scale only the remainder (activation traffic).
    args_rw = 2 * rec["memory"].get("argument_size_in_bytes", 0)
    stack_bytes = max(rec["bytes_per_device"] - args_rw, 0)
    bytes_ = stack_bytes * trips + args_rw
    comp = flops / PEAK_FLOPS
    memt = bytes_ / HBM_BW
    coll_bytes = sum(v["bytes"] for v in rec["collectives"].values()) * trips
    coll = coll_bytes / LINK_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])
    total, active = param_counts(rec["arch"])
    n = active
    if shape.kind == "train":
        model_flops = 6 * n * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n * shape.global_batch  # one token per request
    hlo_total = flops * rec["devices"]
    ratio = model_flops / hlo_total if hlo_total > 0 else 0.0
    peak_gb = (rec["memory"].get("argument_size_in_bytes", 0) +
               rec["memory"].get("temp_size_in_bytes", 0)) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops": model_flops, "useful_ratio": ratio,
        "roofline_fraction": min(comp, memt, coll) and
        (model_flops / rec["devices"] / PEAK_FLOPS) / max(comp, memt, coll),
        "peak_gb_per_dev": peak_gb,
        "fits_16g": peak_gb <= 16.0,
        "collectives": rec["collectives"],
    }


def lever(row) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.25:
            return "compute-bound with low useful ratio: cut remat recompute"
        return "compute-bound near useful peak: only sharper kernels help"
    if d == "memory":
        return "HBM-bound: fuse/bf16-ize the big streams, raise arithmetic"\
            " intensity (larger microbatch per step)"
    return "collective-bound: reshard to cut the dominant collective or "\
        "overlap it with compute"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        rec = json.load(open(path))
        if args.mesh and rec["mesh"] != args.mesh:
            continue
        rows.append(analyze(rec))
    if not rows:
        print("no dry-run artifacts; run python -m repro.launch.dryrun --all")
        return
    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_fraction",
           "peak_gb_per_dev", "fits_16g")
    if args.csv:
        print(",".join(hdr))
        for r in rows:
            print(",".join(f"{r[h]:.4g}" if isinstance(r[h], float)
                           else str(r[h]) for h in hdr))
        return
    print("| " + " | ".join(hdr) + " | lever |")
    print("|" + "---|" * (len(hdr) + 1))
    for r in rows:
        cells = [f"{r[h]:.3g}" if isinstance(r[h], float) else str(r[h])
                 for h in hdr]
        print("| " + " | ".join(cells) + " | " + lever(r) + " |")


if __name__ == "__main__":
    main()
