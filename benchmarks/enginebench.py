"""Engine sweep: N concurrent peers, serial session loops vs ONE engine.

For each peer count the sweep reconciles N independently-stale replicas
against one shared ``SymbolStream`` three ways:

* ``serial`` — N back-to-back :func:`repro.protocol.run_session` loops,
  the pre-engine deployment shape (N separate grow loops);
* ``engine_host`` — one :class:`repro.protocol.ReconcileEngine` driving
  all N sessions in shared ticks on the host peel;
* ``engine_dev`` — the same engine on the device backend, where every
  tick's (peer, window) units coalesce into ONE batched decode per shape
  bucket and the double-buffered pipeline overlaps decode with frame
  ingest.  Timed cold (per-bucket jit compile included) and warm.

Derived columns record ticks and batched dispatches — with one pacing
policy across peers, dispatches == ticks regardless of N, which is the
engine's whole point.  CPU numbers are functional-trajectory only (as
everywhere in this repo); the serving target is TPU.
``benchmarks/run.py`` snapshots the emitted entries into
``BENCH_engine.json`` for the CI perf artifact.
"""
from __future__ import annotations

import numpy as np

from .common import emit, rand_items, timeit

NBYTES = 16
PEER_COUNTS = (1, 2, 4, 8)


def main(quick: bool = True):
    from repro.core import Sketch
    from repro.protocol import (FixedBlock, ReconcileEngine, Session,
                                SymbolStream, run_session)

    n, lost, added = (2000, 80, 16) if quick else (50_000, 1200, 240)
    d = lost + added
    state = rand_items(n, NBYTES, 0)
    stream = SymbolStream.from_items(state, NBYTES)

    def replicas(n_peers):
        out = []
        for p in range(n_peers):
            # disjoint staleness windows so peers do not share a diff
            items = np.concatenate(
                [np.delete(state, slice(p * lost, (p + 1) * lost), axis=0),
                 rand_items(added, NBYTES, 9 + p)])
            out.append(items)
        return out

    for N in PEER_COUNTS:
        locals_ = replicas(N)

        def serial():
            reps = [run_session(
                stream, Session(local=Sketch.from_items(it, NBYTES),
                                pacing=FixedBlock(16)), wire=True)
                for it in locals_]
            return reps

        dt, reps = timeit(serial, repeat=2)
        emit(f"engine_serial_host_N{N}_d{d}", dt * 1e6,
             f"symbols={sum(r.symbols_used for r in reps)} "
             f"overhead={reps[-1].overhead(d):.2f}")

        def engine_run(backend):
            eng = ReconcileEngine()
            for it in locals_:
                eng.register(stream, Session(
                    local=Sketch.from_items(it, NBYTES),
                    pacing=FixedBlock(16), backend=backend), wire=True)
            return eng, eng.run()

        dt, (eng, reps) = timeit(lambda: engine_run("host"), repeat=2)
        emit(f"engine_host_N{N}_d{d}", dt * 1e6,
             f"ticks={eng.ticks} symbols={sum(r.symbols_used for r in reps)}")

        # device backend: one batched dispatch per shape bucket per tick,
        # pipelined with ingest.  Cold includes per-bucket jit compiles.
        dt_cold, (eng, reps) = timeit(lambda: engine_run("device"), repeat=1)
        assert all(r.only_remote.shape[0] == lost for r in reps)
        emit(f"engine_dev_cold_N{N}_d{d}", dt_cold * 1e6,
             f"ticks={eng.ticks} dispatches={eng.dispatches} "
             "(ref engine, includes per-bucket jit compile)")
        dt_warm, (eng, _) = timeit(lambda: engine_run("device"), repeat=2)
        emit(f"engine_dev_warm_N{N}_d{d}", dt_warm * 1e6,
             f"ticks={eng.ticks} dispatches={eng.dispatches} "
             f"us_per_peer={dt_warm * 1e6 / N:.1f}")


if __name__ == "__main__":
    main()
