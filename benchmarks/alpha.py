"""Paper Fig. 14 — communication overhead η*(α) from density evolution.

Claim: η* is minimized near α = 0.5 (the design point that also makes the
skip-sampling CDF collapse to a closed form), with η*(0.5) ≈ 1.35.
"""
from __future__ import annotations

from repro.core import de

from .common import emit


def main(quick: bool = True):
    alphas = [0.25, 0.4, 0.5, 0.65, 0.8, 1.0] if quick else \
        [0.1, 0.2, 0.3, 0.4, 0.45, 0.5, 0.55, 0.6, 0.7, 0.8, 1.0, 1.2, 1.5]
    best = (None, float("inf"))
    for a in alphas:
        eta = de.eta_star(a)
        if eta < best[1]:
            best = (a, eta)
        emit(f"fig14_eta_star_alpha{a}", 0.0, f"eta_star={eta:.4f}")
    emit("fig14_minimum", 0.0, f"alpha={best[0]} eta={best[1]:.4f}")


if __name__ == "__main__":
    main()
