"""Benchmark runner — one module per paper figure/table.

``python -m benchmarks.run``            quick CI-scale sweep
``python -m benchmarks.run --full``     paper-scale sweep (slow)
``python -m benchmarks.run --only fig7``
``python -m benchmarks.run --roofline`` include roofline table rendering
                                        (requires dry-run artifacts)

Output: ``name,us_per_call,derived`` CSV on stdout.  When the kernel or
shard suites run, their entries are additionally written to
``BENCH_kernels.json`` / ``BENCH_shards.json`` as machine-readable
``{name: µs}`` maps so CI can record the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig7 / statesync / kernel")
    ap.add_argument("--roofline", action="store_true",
                    help="render roofline table from dry-run artifacts")
    args = ap.parse_args()

    from . import (alpha, enginebench, itemsize, kernelbench, overhead,
                   setsize, shardbench, statesync, throughput, wirebench)
    suites = [
        ("overhead", overhead),      # Figs 4, 6
        ("throughput", throughput),  # Figs 7, 8
        ("setsize", setsize),        # Fig 9
        ("itemsize", itemsize),      # Fig 10
        ("statesync", statesync),    # Figs 11, 12
        ("alpha", alpha),            # Fig 14
        ("kernelbench", kernelbench),  # device-encoder kernel (framework)
        ("wirebench", wirebench),    # §6 wire codec: vectorized vs loop
        ("shardbench", shardbench),  # sharded serving + batched decode
        ("enginebench", enginebench),  # N-peer engine vs serial sessions
    ]
    artifacts = {"kernelbench": "BENCH_kernels.json",
                 "shardbench": "BENCH_shards.json",
                 "enginebench": "BENCH_engine.json"}
    from .common import RESULTS
    failed = []
    for name, mod in suites:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        before = set(RESULTS)
        try:
            mod.main(quick=not args.full)
        except Exception as e:  # keep the suite going; report the failure
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            failed.append(name)
        print(f"# === {name} done in {time.time() - t0:.1f}s ===", flush=True)
        if name in artifacts and name not in failed:
            entries = {k: round(v, 2) for k, v in RESULTS.items()
                       if k not in before}
            with open(artifacts[name], "w") as f:
                json.dump(entries, f, indent=2, sort_keys=True)
            print(f"# wrote {artifacts[name]} ({len(entries)} entries)",
                  flush=True)
    if args.roofline:  # independent of suite outcomes — render before exit
        from . import roofline
        roofline.main()

    if failed:  # exit nonzero so CI smoke steps actually catch breakage
        print(f"# FAILED suites: {', '.join(failed)}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
