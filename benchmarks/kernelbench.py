"""Device-encoder microbench (framework-side, not a paper figure).

Times the two Pallas kernels in interpret mode (functional check only —
interpret timings are NOT device timings; real perf analysis for the TPU
target lives in EXPERIMENTS.md §Roofline/§Perf where we reason from the
lowered HLO) and the host encoder they are validated against.
"""
from __future__ import annotations

import numpy as np

from .common import emit, timeit

ITEM_WORDS = 2  # 8-byte items, as in paper §7.2


def main(quick: bool = True):
    import jax.numpy as jnp

    from repro.core.encoder import encode
    from repro.kernels.ops import encode_device

    n, m = (2048, 512) if quick else (16384, 4096)
    items = np.random.default_rng(1).integers(
        0, 2**32, size=(n, ITEM_WORDS), dtype=np.uint32)

    dt, _ = timeit(lambda: encode(items, 4 * ITEM_WORDS, m), repeat=2)
    emit(f"host_encode_n{n}_m{m}", dt * 1e6,
         f"MBps={n * 4 * ITEM_WORDS / dt / 1e6:.1f}")

    ji = jnp.asarray(items)
    dt, _ = timeit(lambda: encode_device(ji, m=m, nbytes=4 * ITEM_WORDS),
                   repeat=1)
    emit(f"device_encode_interpret_n{n}_m{m}", dt * 1e6,
         "(interpret-mode functional check, not TPU timing)")


if __name__ == "__main__":
    main()
