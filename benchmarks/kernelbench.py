"""Device encode/decode microbench (framework-side, not a paper figure).

Times the device pipelines on this host (functional check only — CPU
timings are NOT device timings; real perf analysis for the TPU target
lives in EXPERIMENTS.md §Roofline/§Perf where we reason from the lowered
HLO) and the host encoder/decoder they are validated against.  The decode
entries time the wave-peeling ref engine twice: cold (per-shape-bucket jit
compile included) and warm (the steady-state a stream decoder sees).
"""
from __future__ import annotations

import numpy as np

from .common import emit, timeit

ITEM_WORDS = 2  # 8-byte items, as in paper §7.2


def main(quick: bool = True):
    import jax.numpy as jnp

    from repro.core.decoder import peel
    from repro.core.encoder import Encoder, encode
    from repro.kernels.ops import (decode_device, encode_device,
                                   host_symbols_to_device)

    n, m = (2048, 512) if quick else (16384, 4096)
    items = np.random.default_rng(1).integers(
        0, 2**32, size=(n, ITEM_WORDS), dtype=np.uint32)

    dt, _ = timeit(lambda: encode(items, 4 * ITEM_WORDS, m), repeat=2)
    emit(f"host_encode_n{n}_m{m}", dt * 1e6,
         f"MBps={n * 4 * ITEM_WORDS / dt / 1e6:.1f}")

    ji = jnp.asarray(items)
    dt, _ = timeit(lambda: encode_device(ji, m=m, nbytes=4 * ITEM_WORDS),
                   repeat=1)
    emit(f"device_encode_interpret_n{n}_m{m}", dt * 1e6,
         "(interpret-mode functional check, not TPU timing)")

    # -- decode: difference of two sets, d items recoverable within m ------
    d = m // 4
    nbytes = 4 * ITEM_WORDS
    A, B = Encoder(nbytes), Encoder(nbytes)
    A.add_items(items)
    B.add_items(items[:-d])
    diff = A.symbols(m).subtract(B.symbols(m))

    dt, res = timeit(lambda: peel(diff), repeat=2)
    assert res.success
    emit(f"host_peel_d{d}_m{m}", dt * 1e6,
         f"rounds={res.rounds} us_per_item={dt * 1e6 / d:.1f}")

    dev = host_symbols_to_device(diff)
    dt_cold, res = timeit(
        lambda: decode_device(*dev, nbytes=nbytes), repeat=1)
    assert res.success
    emit(f"device_decode_cold_d{d}_m{m}", dt_cold * 1e6,
         "(ref engine, includes jit compile)")
    dt_warm, _ = timeit(lambda: decode_device(*dev, nbytes=nbytes), repeat=2)
    emit(f"device_decode_warm_d{d}_m{m}", dt_warm * 1e6,
         f"waves={res.rounds} us_per_item={dt_warm * 1e6 / d:.1f}")


if __name__ == "__main__":
    main()
