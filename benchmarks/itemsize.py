"""Paper Fig. 10 — slowdown when encoding items of growing size ℓ.

Claim: sublinear slowdown while fixed per-item costs dominate (ℓ ≤ ~2 KB),
then linear (XOR bandwidth-bound); i.e. bytes/s throughput flattens.
"""
from __future__ import annotations

from .common import emit, make_sets, timeit

N = 5_000
D = 100


def main(quick: bool = True):
    sizes = [8, 32, 128, 1024, 4096] if quick else \
        [8, 32, 128, 512, 2048, 8192, 32768]
    base = None
    m = int(1.6 * D)
    for nbytes in sizes:
        from repro.core import Encoder
        a, _, _, _ = make_sets(N - D, D, 0, nbytes)

        def run():
            e = Encoder(nbytes)
            e.add_items(a)
            return e.symbols(m)

        dt, _ = timeit(run, repeat=2)
        if base is None:
            base = dt
        emit(f"fig10_itemsize_{nbytes}B", dt * 1e6,
             f"slowdown={dt / base:.2f} MBps={N * nbytes / dt / 1e6:.1f}")


if __name__ == "__main__":
    main()
