"""Sharded-serving sweep: reconcile one diff sharded S ∈ {1, 2, 4, 8} ways.

For each shard count the sweep times the full merged-payload protocol loop
(:func:`repro.protocol.run_sharded_session`) on the host backend, plus the
batched device decode (`decode_device_batched` — the peel wave vmapped over
the shard axis) cold (per-bucket jit compile included) and warm.  Derived
columns record total symbols at decode and the overhead factor so the
wire-cost side of sharding is tracked together with the time side.

CPU numbers are functional-trajectory only (as everywhere in this repo);
the serving target is TPU, where the batched decode is one fused program.
``benchmarks/run.py`` snapshots the emitted entries into
``BENCH_shards.json`` for the CI perf artifact.
"""
from __future__ import annotations

import numpy as np

from .common import emit, make_sets, timeit

NBYTES = 16
SHARD_COUNTS = (1, 2, 4, 8)


def main(quick: bool = True):
    from repro.kernels.ops import decode_device_batched
    from repro.protocol import FixedBlock, ShardedStream, run_sharded_session

    n, d_lost, d_add = (3000, 160, 40) if quick else (50_000, 1600, 400)
    d = d_lost + d_add
    a_items, b_items, _, _ = make_sets(n, d_lost, d_add, NBYTES)

    for S in SHARD_COUNTS:
        stream = ShardedStream.from_items(a_items, NBYTES, n_shards=S)
        local = ShardedStream.from_items(b_items, NBYTES, n_shards=S)

        def sync():
            return run_sharded_session(
                stream, stream.session(local=local, pacing=FixedBlock(16)),
                wire=True)

        dt, rep = timeit(sync, repeat=2)
        emit(f"shard_sync_host_S{S}_d{d}", dt * 1e6,
             f"symbols={rep.symbols_used} overhead={rep.overhead(d):.2f} "
             f"steps={rep.grow_steps} wire_B={rep.bytes_received}")

        # batched device decode of the S residual prefixes in one call:
        # reuse the host run's per-shard reach as realistic prefix lengths
        shards = []
        for s in range(S):
            m_s = max(rep.shards[s].symbols_received, 8)
            diff = stream.shards[s].window(0, m_s).subtract(
                local.shards[s].encoder.symbols(m_s))
            shards.append(diff)
        # quick: a tight fixed-shape bound; full: the safe default (= the
        # padded prefix, which can never overflow even at S=1)
        max_diff = 256 if quick else None
        dt_cold, res = timeit(
            lambda: decode_device_batched(shards, nbytes=NBYTES,
                                          max_diff=max_diff), repeat=1)
        assert all(r.success for r in res), "batched decode must converge"
        emit(f"shard_decode_batched_cold_S{S}_d{d}", dt_cold * 1e6,
             "(ref engine, includes per-bucket jit compile)")
        dt_warm, _ = timeit(
            lambda: decode_device_batched(shards, nbytes=NBYTES,
                                          max_diff=max_diff), repeat=2)
        emit(f"shard_decode_batched_warm_S{S}_d{d}", dt_warm * 1e6,
             f"waves={max(r.rounds for r in res)} "
             f"us_per_item={dt_warm * 1e6 / d:.1f}")


if __name__ == "__main__":
    main()
