"""Paper Figs. 11/12 (and 15/16) — state synchronization application.

The paper syncs Ethereum account state (20 B keys, 72 B values) between a
fresh and a stale replica over a 50 ms / 20 Mbps link, comparing Rateless
IBLT against Merkle-trie "state heal".  Here the state is this framework's
own checkpoint-chunk manifest (the sync substrate of `repro.checkpoint`):
records of key (20 B) + chunk digest/value (72 B) — byte-identical geometry
to the paper's workload.

Completion-time model: rounds·RTT + bytes/bandwidth + measured CPU time —
the same three terms that govern the paper's testbed numbers (their system
is throughput-bound for riblt, round-trip/compute-bound for state heal).
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit, make_sets

KEY_B = 20
VAL_B = 72
ITEM = KEY_B + VAL_B          # one record = one set item, as in the paper
RTT = 0.100                   # 2 × 50 ms propagation
BW = 20e6 / 8                 # 20 Mbps in bytes/s


def riblt_cost(a, b, d):
    """Bytes from the exact decodable prefix (window-streamed by a protocol
    Session, like the wire path); CPU from bulk encode+decode (symbols
    arrive at line rate and are decoded incrementally — the paper's Bob is
    throughput-bound)."""
    from repro.core import Encoder, peel
    from repro.protocol import Exponential, Session, SymbolStream, run_session
    A = Encoder(ITEM)
    A.add_items(a)
    B = Encoder(ITEM)
    B.add_items(b)
    rep = run_session(SymbolStream(A),
                      Session(local=B,
                              pacing=Exponential(block=64, growth=1.5)))
    m = rep.symbols_used
    # CPU cost: fresh bulk encode of the used prefix + one-shot peel
    t0 = time.perf_counter()
    A2 = Encoder(ITEM)
    A2.add_items(a)
    sa = A2.symbols(m)
    B2 = Encoder(ITEM)
    B2.add_items(b)
    sb = B2.symbols(m)
    res = peel(sa.subtract(sb))
    cpu = time.perf_counter() - t0
    assert res.success
    sym_bytes = ITEM + 8 + 1.05
    bytes_moved = m * sym_bytes
    completion = RTT + bytes_moved / BW + cpu
    return bytes_moved, completion, m


def merkle_cost(a, b):
    from repro.core.baselines.merkle import MerkleTrieSync
    from repro.core.hashing import bytes_to_words
    t0 = time.perf_counter()
    ta = MerkleTrieSync(bytes_to_words(a, ITEM), ITEM)
    tb = MerkleTrieSync(bytes_to_words(b, ITEM), ITEM)
    by, rounds, leaves = ta.sync_cost(tb, value_bytes=0)
    cpu = time.perf_counter() - t0
    completion = rounds * RTT + by / BW + cpu
    return by, completion, rounds


def main(quick: bool = True):
    N = 50_000 if quick else 500_000
    # staleness → difference size: model an update rate like the paper's
    # trace (~300 differing accounts per hour of staleness at this N).
    for hours, d in ([(1, 300), (10, 3000)] if quick else
                     [(1, 300), (3, 900), (10, 3000), (30, 9000)]):
        a, b, _, _ = make_sets(N - d, d // 2, d - d // 2, ITEM)
        rb, rt, m = riblt_cost(a, b, d)
        mb, mt, rounds = merkle_cost(a, b)
        emit(f"fig11_riblt_stale{hours}h", rt * 1e6,
             f"bytes={rb / 1e6:.2f}MB completion={rt:.2f}s m={m}")
        emit(f"fig11_merkle_stale{hours}h", mt * 1e6,
             f"bytes={mb / 1e6:.2f}MB completion={mt:.2f}s rounds={rounds}")
        emit(f"fig11_gain_stale{hours}h", 0.0,
             f"time_gain={mt / rt:.1f}x bytes_gain={mb / rb:.1f}x")
    # Fig 12: completion vs bandwidth at fixed staleness
    d = 3000 if quick else 9000
    a, b, _, _ = make_sets(N - d, d // 2, d - d // 2, ITEM)
    rbytes, rcomp, m = riblt_cost(a, b, d)
    cpu_r = rcomp - RTT - rbytes / BW
    from repro.core.baselines.merkle import MerkleTrieSync
    from repro.core.hashing import bytes_to_words
    t0 = time.perf_counter()
    ta = MerkleTrieSync(bytes_to_words(a, ITEM), ITEM)
    tb = MerkleTrieSync(bytes_to_words(b, ITEM), ITEM)
    mby, rounds, _ = ta.sync_cost(tb, value_bytes=0)
    cpu_m = time.perf_counter() - t0
    for mbps in (10, 20, 50, 100):
        bw = mbps * 1e6 / 8
        rt = RTT + rbytes / bw + cpu_r
        mt = rounds * RTT + mby / bw + cpu_m
        emit(f"fig12_bw{mbps}Mbps", 0.0,
             f"riblt={rt:.2f}s merkle={mt:.2f}s gain={mt / rt:.1f}x")


if __name__ == "__main__":
    main()
