"""Paper Figs. 4 & 6 — communication overhead vs difference size.

Overhead = symbols needed to decode / d (Fig 4, Rateless IBLT), and
bytes transmitted / (d·ℓ) across schemes (Fig 6; ℓ = 32-byte items).

Paper's claims: Rateless IBLT peaks ~1.72 at d≈4, converges to ~1.35 by
d in the low hundreds; regular IBLT needs 3–4× more (plus a ≥15 KB
estimator); PinSketch/CPI sits at 1.0; Merkle trie ≥ 40.
"""
from __future__ import annotations

import numpy as np

from .common import emit, make_sets, rand_items, riblt_symbols_to_decode

ITEM = 32
ESTIMATOR_BYTES = 15_000  # recommended set-difference estimator cost [15]


def riblt_overhead(d: int, trials: int, n_common: int = 200) -> tuple[float, float]:
    used = []
    for _ in range(trials):
        da = d // 2
        db = d - da
        a, b, _, _ = make_sets(n_common, da, db, ITEM)
        used.append(riblt_symbols_to_decode(a, b, ITEM))
    used = np.array(used, float) / d
    return float(used.mean()), float(used.std())


def regular_overhead(d: int, trials: int, success_target: float = 0.95,
                     n_common: int = 200) -> float:
    """Minimal m/d with ≥ success_target decode rate (paper used 1-1/3000
    with far more trials; we document the reduced target for CI speed)."""
    from repro.core.baselines.regular_iblt import reconcile_regular
    m = max(8, int(1.2 * d))
    while True:
        ok = 0
        for _ in range(trials):
            da = d // 2
            db = d - da
            a, b, ai, bi = make_sets(n_common, da, db, ITEM)
            from repro.core.hashing import bytes_to_words
            _, _, success = reconcile_regular(bytes_to_words(a, ITEM),
                                              bytes_to_words(b, ITEM),
                                              m=m, nbytes=ITEM)
            ok += success
        if ok / trials >= success_target:
            return m / d
        m = int(m * 1.25) + 1


def met_overhead(d: int, trials: int, n_common: int = 200) -> float:
    """Nested MET-IBLT: smallest usable rate-step prefix that decodes."""
    from repro.core.baselines.met_iblt import MetIBLT
    from repro.core.hashing import bytes_to_words
    used = []
    for _ in range(trials):
        da = d // 2
        db = d - da
        a, b, _, _ = make_sets(n_common, da, db, ITEM)
        m0, steps = 16, 8
        A = MetIBLT(m0, steps, ITEM)
        B = MetIBLT(m0, steps, ITEM)
        A.insert(bytes_to_words(a, ITEM))
        B.insert(bytes_to_words(b, ITEM))
        got = None
        for s in range(steps):
            _, _, ok = A.decode(A.prefix(s).subtract(B.prefix(s)))
            if ok:
                got = A.prefix(s).m
                break
        used.append((got if got else A.m) / d)
    return float(np.mean(used))


def main(quick: bool = True):
    ds = [1, 2, 4, 8, 16, 32, 64, 128, 256] if quick else \
        [1, 2, 4, 8, 16, 32, 64, 128, 256, 400, 1024]
    trials = 12 if quick else 100
    sym_bytes = ITEM + 8 + 1.05  # sum + checksum + varint count (§6)
    for d in ds:
        mean, std = riblt_overhead(d, trials)
        emit(f"fig4_riblt_overhead_d{d}", 0.0,
             f"overhead={mean:.3f} std={std:.3f}")
        emit(f"fig6_riblt_bytes_d{d}", 0.0,
             f"byte_overhead={mean * sym_bytes / ITEM:.3f}")
    for d in ([4, 16, 64, 256] if quick else ds):
        ov = regular_overhead(d, max(trials // 2, 6))
        reg_bytes = ov * (ITEM + 8 + 8) / ITEM  # 8B checksum + 8B count [7]
        emit(f"fig6_regular_iblt_d{d}", 0.0,
             f"byte_overhead={reg_bytes:.3f} "
             f"with_estimator={reg_bytes + ESTIMATOR_BYTES / (ITEM * d):.3f}")
        mv = met_overhead(d, max(trials // 2, 6))
        emit(f"fig6_met_iblt_d{d}", 0.0,
             f"byte_overhead={mv * (ITEM + 8 + 8) / ITEM:.3f}")
    emit("fig6_cpi_pinsketch", 0.0, "byte_overhead=1.0 (m=d by construction)")
    # Merkle trie for context (paper: >40 at all d here)
    from repro.core.baselines.merkle import MerkleTrieSync
    from repro.core.hashing import bytes_to_words
    d = 64
    a, b, _, _ = make_sets(100_000 if not quick else 20_000, d // 2,
                           d - d // 2, ITEM)
    ta = MerkleTrieSync(bytes_to_words(a, ITEM), ITEM)
    tb = MerkleTrieSync(bytes_to_words(b, ITEM), ITEM)
    by, rounds, _ = ta.sync_cost(tb, value_bytes=0)
    emit(f"fig6_merkle_d{d}", 0.0,
         f"byte_overhead={by / (d * ITEM):.1f} rounds={rounds}")


if __name__ == "__main__":
    main()
