"""Paper Fig. 9 — encoding time vs set size N at fixed difference.

Claim: encode cost is linear in N (each source symbol does the same
O(log m) mapping work), while decode cost is independent of N.
"""
from __future__ import annotations

from .common import emit, make_sets, timeit

ITEM = 8
D = 100


def main(quick: bool = True):
    Ns = [1_000, 10_000, 100_000] if quick else \
        [1_000, 10_000, 100_000, 1_000_000]
    m = int(1.6 * D)
    base = None
    for N in Ns:
        from repro.core import Encoder
        a, _, _, _ = make_sets(N - D, D, 0, ITEM)

        def run():
            e = Encoder(ITEM)
            e.add_items(a)
            return e.symbols(m)

        dt, _ = timeit(run, repeat=2)
        if base is None:
            base = (N, dt)
        emit(f"fig9_encode_N{N}_d{D}", dt * 1e6,
             f"time_ratio={dt / base[1]:.2f} size_ratio={N / base[0]:.0f} "
             f"MBps={N * ITEM / dt / 1e6:.1f}")


if __name__ == "__main__":
    main()
