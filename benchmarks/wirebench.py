"""Wire codec micro-benchmark: vectorized vs per-symbol-loop frame codecs.

The protocol layer serializes every window a session pulls, so codec
throughput bounds the wire path the same way encode throughput bounds the
symbol path.  Measures symbols/sec for serialize and deserialize on the
same frames (`encode_frames` vs `encode_frames_loop`, both producing
byte-identical output).
"""
from __future__ import annotations

from .common import emit, rand_items, timeit


def main(quick: bool = True):
    from repro.core import encode
    from repro.core.wire import (decode_frames, decode_frames_loop,
                                 encode_frames, encode_frames_loop)
    m = 2048 if quick else 16384
    repeat = 3 if quick else 5
    for nbytes in (16, 92):
        items = rand_items(4 * m, nbytes)
        sym = encode(items, nbytes, m)
        blob = encode_frames(sym)
        assert blob == encode_frames_loop(sym)  # identical wire format
        times = {}
        for name, fn, arg in (
                ("enc_vec", encode_frames, sym),
                ("enc_loop", encode_frames_loop, sym),
                ("dec_vec", decode_frames, blob),
                ("dec_loop", decode_frames_loop, blob)):
            t, _ = timeit(fn, arg, repeat=repeat)
            times[name] = t
            emit(f"wire_{name}_l{nbytes}", t / m * 1e6,
                 f"{m / t / 1e6:.2f}Msym/s bytes/sym="
                 f"{len(blob) / m:.1f}")
        emit(f"wire_speedup_l{nbytes}", 0.0,
             f"encode {times['enc_loop'] / times['enc_vec']:.0f}x "
             f"decode {times['dec_loop'] / times['dec_vec']:.0f}x "
             f"(vectorized over loop)")


if __name__ == "__main__":
    main()
