"""Paper Figs. 7 & 8 — encoding/decoding throughput vs difference size.

Encoding throughput = d / time for Alice to produce enough coded symbols
(~1.35d) for a set of N items.  Decoding throughput = d / time to peel.
Items are 8 bytes (the paper fixes ℓ=8 to match PinSketch's limit).

Expected qualitative behavior (paper §7.2): Rateless IBLT encode time grows
~logarithmically in d (sparse mapping) while CPI/PinSketch grows linearly;
decode is O(d·log d) vs O(d²) [here O(d³): textbook interpolation].
"""
from __future__ import annotations

import numpy as np

from .common import emit, make_sets, timeit

ITEM = 8


def riblt_encode_bench(N: int, d: int, repeat=3):
    from repro.core import Encoder
    a, _, _, _ = make_sets(N - d, d, 0, ITEM)
    m = int(1.35 * d) + 2

    def run():
        enc = Encoder(ITEM)
        enc.add_items(a)
        return enc.symbols(m)

    dt, _ = timeit(run, repeat=repeat)
    return dt


def riblt_decode_bench(d: int, repeat=3):
    from repro.core import Encoder, peel
    a, b, _, _ = make_sets(0, d // 2, d - d // 2, ITEM)
    m = 8 + int(2.0 * d)  # enough to decode comfortably
    A = Encoder(ITEM)
    A.add_items(a)
    B = Encoder(ITEM)
    if len(b):
        B.add_items(b)
    diff = A.symbols(m).subtract(B.symbols(m))
    dt, res = timeit(peel, diff, repeat=repeat)
    assert res.success
    return dt


def cpi_encode_bench(N: int, d: int, repeat=1):
    from repro.core.baselines.cpi import CPISketch
    from repro.core.hashing import bytes_to_words
    a, _, _, _ = make_sets(N - d, d, 0, ITEM)
    aw = bytes_to_words(a, ITEM)

    def run():
        s = CPISketch(d, ITEM)
        s.insert(aw)
        return s

    dt, _ = timeit(run, repeat=repeat)
    return dt


def cpi_decode_bench(d: int, repeat=1):
    from repro.core.baselines.cpi import CPISketch
    from repro.core.hashing import bytes_to_words
    a, b, _, _ = make_sets(50, d // 2, d - d // 2, ITEM)
    m = d + 2
    A = CPISketch(m, ITEM)
    B = CPISketch(m, ITEM)
    A.insert(bytes_to_words(a, ITEM))
    B.insert(bytes_to_words(b, ITEM))
    dt, out = timeit(A.decode_against, B, d_bound=d, repeat=repeat)
    assert out[2], "CPI decode failed"
    return dt


def main(quick: bool = True):
    Ns = [10_000] if quick else [10_000, 1_000_000]
    ds = [10, 100, 1000] if quick else [2, 10, 100, 1000, 10_000, 100_000]
    for N in Ns:
        for d in ds:
            if d >= N:
                continue
            dt = riblt_encode_bench(N, d)
            emit(f"fig7_riblt_encode_N{N}_d{d}", dt * 1e6,
                 f"items_per_s={N / dt:.0f} diffs_per_s={d / dt:.0f} "
                 f"MBps={N * ITEM / dt / 1e6:.1f}")
    for d in ds:
        dt = riblt_decode_bench(d)
        emit(f"fig8_riblt_decode_d{d}", dt * 1e6,
             f"diffs_per_s={d / dt:.0f}")
    cpi_ds = [10, 50, 100] if quick else [10, 50, 100, 256]
    for d in cpi_ds:
        dt = cpi_encode_bench(10_000, d)
        emit(f"fig7_cpi_encode_N10000_d{d}", dt * 1e6,
             f"diffs_per_s={d / dt:.0f}")
        dt = cpi_decode_bench(d)
        emit(f"fig8_cpi_decode_d{d}", dt * 1e6, f"diffs_per_s={d / dt:.0f}")


if __name__ == "__main__":
    main()
