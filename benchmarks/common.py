"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np

RNG = np.random.default_rng(0xB0B)


def rand_items(n: int, nbytes: int, tag: int = 0) -> np.ndarray:
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if n:
        out[:, -1] = tag
    return out


def make_sets(n_common: int, da: int, db: int, nbytes: int):
    common = rand_items(n_common, nbytes, 0)
    ai = rand_items(da, nbytes, 1)
    bi = rand_items(db, nbytes, 2)
    return (np.concatenate([common, ai]), np.concatenate([common, bi]),
            ai, bi)


def timeit(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


# name -> µs/call for every emit() since process start; benchmarks/run.py
# snapshots this around each suite to build machine-readable artifacts
# (BENCH_kernels.json) for perf-trajectory tracking.
RESULTS: dict[str, float] = {}


def emit(name: str, us_per_call: float, derived: str):
    RESULTS[name] = us_per_call
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def riblt_symbols_to_decode(set_a, set_b, nbytes, key=None) -> int:
    """Exact minimal prefix length that decodes (one-symbol stream steps)."""
    from repro.core import Encoder
    from repro.core.hashing import DEFAULT_KEY
    from repro.protocol import FixedBlock, Session, SymbolStream, run_session
    key = key or DEFAULT_KEY
    A = Encoder(nbytes, key)
    B = Encoder(nbytes, key)
    if len(set_a):
        A.add_items(set_a)
    if len(set_b):
        B.add_items(set_b)
    rep = run_session(SymbolStream(A),
                      Session(local=B, pacing=FixedBlock(1)))
    return rep.symbols_used
