"""Docs gate: link-check README + docs/, run README snippets + doctests.

Three checks, all offline, no dependencies beyond the library's own:

1. **Links** — every relative markdown link in README.md and docs/*.md
   must point at an existing file (anchors are stripped; http(s)/mailto
   links are skipped — CI has no network guarantees).
2. **README snippets** — every ```python fenced block in README.md is
   executed top-to-bottom in one shared namespace, so the quickstart can't
   rot: if the API changes and the README doesn't, this job fails.
3. **Doctests** — ``doctest.testmod`` over the ``repro.protocol`` modules
   (the pacing policies carry executable examples).

Run from the repo root:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import pathlib
import re
import sys
import traceback

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def check_links() -> list[str]:
    errors = []
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    n = 0
    for md in files:
        text = md.read_text()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n += 1
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    print(f"link check: {n} relative links across {len(files)} files")
    return errors


def run_readme_snippets() -> list[str]:
    blocks = _FENCE.findall((ROOT / "README.md").read_text())
    if not blocks:
        return ["README.md: no ```python snippets found (expected >= 1)"]
    ns: dict = {"__name__": "__readme__"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"README.md[python #{i}]", "exec"), ns)
        except Exception:
            return [f"README.md python block #{i} failed:\n"
                    f"{traceback.format_exc()}"]
    print(f"readme snippets: {len(blocks)} python blocks executed")
    return []


def run_doctests() -> list[str]:
    import repro.protocol.engine
    import repro.protocol.pacing
    import repro.protocol.reports
    import repro.protocol.session
    import repro.protocol.sharded
    import repro.protocol.stream
    errors = []
    total = 0
    for mod in (repro.protocol.engine, repro.protocol.pacing,
                repro.protocol.reports, repro.protocol.session,
                repro.protocol.sharded, repro.protocol.stream):
        res = doctest.testmod(mod, verbose=False)
        total += res.attempted
        if res.failed:
            errors.append(f"doctest: {res.failed} failure(s) in "
                          f"{mod.__name__}")
    print(f"doctests: {total} examples across repro.protocol")
    if total == 0:
        errors.append("doctest: no examples found in repro.protocol "
                      "(expected >= 1)")
    return errors


def main() -> int:
    errors = check_links() + run_readme_snippets() + run_doctests()
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    print("docs check:", "FAILED" if errors else "OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
