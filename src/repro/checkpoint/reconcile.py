"""Checkpoint reconciliation via Rateless IBLT — the paper's technique as
the framework's state-repair path (paper §7.3's Ethereum scenario, with the
ledger replaced by a checkpoint store).

A stale/corrupt replica holds store B; a healthy peer holds store A.  The
stores' manifests are sets of 16-byte records (key-hash ‖ chunk-digest).
The peer exposes one universal `SymbolStream` (it can serve any number of
replicas at any staleness with the same stream — §4.1 universality); each
replica runs a `repro.protocol.Session` over the byte-level wire frames,
subtracting its own symbols, peeling as frames arrive, and learns exactly
which chunk ids differ — then fetches only those chunks.  No
difference-size estimate, no round trips beyond the fetch.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import CodedSymbols, Sketch
from repro.core.hashing import siphash24
from repro.protocol import Exponential, Session, SymbolStream

REC_BYTES = 16


@dataclasses.dataclass
class SyncReport:
    symbols_used: int
    symbol_bytes: int      # actual wire traffic of the symbol frames
    chunks_fetched: int
    chunk_bytes: int
    naive_bytes: int       # cost of downloading the full store

    @property
    def total_bytes(self):
        return self.symbol_bytes + self.chunk_bytes

    @property
    def savings(self):
        return self.naive_bytes / max(self.total_bytes, 1)


def _cid_hash(cid: str) -> int:
    return int(siphash24(np.frombuffer(
        cid.encode().ljust(64, b"\0")[:64], np.uint8)
        .view(np.uint32)[None, :])[0])


def _record_key_hashes(recs: np.ndarray) -> np.ndarray:
    """(n, 4) uint32 record words -> (n,) uint64 leading key-hash halves."""
    if recs.shape[0] == 0:
        return np.zeros(0, np.uint64)
    w = np.ascontiguousarray(recs[:, :2]).astype(np.uint64)
    return w[:, 0] | (w[:, 1] << np.uint64(32))


class PeerEndpoint:
    """The healthy side: serves coded-symbol wire frames + chunk bodies.

    The symbol stream is universal and incremental: it is extended on
    demand and reused across every syncing replica; when the store changes,
    the cached prefix is *updated* (add/remove the delta records) instead of
    rebuilt — the paper's linearity property."""

    def __init__(self, store):
        self.store = store
        self.stream = SymbolStream(Sketch.from_items(store.records(),
                                                     REC_BYTES))
        self._cid_by_key: dict[int, str] = {}
        self._kh_by_cid: dict[str, int] = {}
        self._refresh_cid_map()

    def _refresh_cid_map(self):
        """Sync the kh→cid map with the manifest, hashing only the delta."""
        chunks = self.store.manifest()["chunks"].keys()
        for cid in self._kh_by_cid.keys() - chunks:
            self._cid_by_key.pop(self._kh_by_cid.pop(cid), None)
        for cid in chunks - self._kh_by_cid.keys():
            kh = _cid_hash(cid)
            self._kh_by_cid[cid] = kh
            self._cid_by_key[kh] = cid

    def frames(self, lo: int, hi: int) -> bytes:
        """Wire frame for symbols [lo, hi) of the universal stream."""
        return self.stream.frames(lo, hi)

    def symbols(self, lo: int, hi: int) -> CodedSymbols:
        """Deprecated shim (pre-session API): raw symbol window [lo, hi)."""
        return self.stream.window(lo, hi).copy()

    def fetch_chunk(self, cid: str) -> bytes:
        with open(self.store._chunk_path(cid), "rb") as f:
            return f.read()

    def notify_update(self, added: np.ndarray, removed: np.ndarray):
        """Store changed: update the universal symbol cache in place."""
        if len(added):
            self.stream.add_items(added)
        if len(removed):
            self.stream.remove_items(removed)
        self._refresh_cid_map()


def sync_from_peer(store, peer: PeerEndpoint, block: int = 16,
                   max_m: int = 1 << 20) -> SyncReport:
    """Repair `store` to match `peer.store`.  Returns transfer accounting."""
    local = Sketch.from_items(store.records(), REC_BYTES)
    session = Session(local=local,
                      pacing=Exponential(block=block, growth=1.5),
                      max_m=max_m)
    while (win := session.request()) is not None:
        session.offer_bytes(peer.frames(*win))
    rep = session.report()
    only_peer, only_local = rep.only_remote, rep.only_local
    man = store.manifest()
    peer_man = peer.store.manifest()
    # map recovered records back to chunk ids via the key-hash half
    fetched = 0
    fetched_bytes = 0
    for kh in _record_key_hashes(only_peer):
        cid = peer._cid_by_key.get(int(kh))
        if cid is None:
            continue
        data = peer.fetch_chunk(cid)
        with open(store._chunk_path(cid), "wb") as f:
            f.write(data)
        man["chunks"][cid] = peer_man["chunks"][cid]
        fetched += 1
        fetched_bytes += len(data)
    # records only in the stale store = chunks that no longer exist
    # upstream; one reverse key-hash map, built once, replaces the old
    # per-record rescan of the whole manifest.
    key_to_cid = {_cid_hash(cid): cid for cid in man["chunks"]}
    for kh in _record_key_hashes(only_local):
        cid = key_to_cid.get(int(kh))
        if cid is not None and cid not in peer_man["chunks"]:
            man["chunks"].pop(cid, None)
    man["leaves"] = peer_man["leaves"]
    man["step"] = peer_man["step"]
    with open(os.path.join(store.root, "manifest.json"), "w") as f:
        json.dump(man, f)
    naive = sum(len(peer.fetch_chunk(cid)) for cid in peer_man["chunks"])
    return SyncReport(symbols_used=rep.symbols_used,
                      symbol_bytes=rep.bytes_received,
                      chunks_fetched=fetched, chunk_bytes=fetched_bytes,
                      naive_bytes=naive)
