"""Checkpoint reconciliation via Rateless IBLT — the paper's technique as
the framework's state-repair path (paper §7.3's Ethereum scenario, with the
ledger replaced by a checkpoint store).

A stale/corrupt replica holds store B; a healthy peer holds store A.  The
stores' manifests are sets of 16-byte records (key-hash ‖ chunk-digest).
The peer streams *universal* coded symbols (it can serve any number of
replicas at any staleness with the same stream — §4.1 universality); the
replica subtracts its own symbols, peels, learns exactly which chunk ids
differ, and fetches only those chunks.  No difference-size estimate, no
round trips beyond the fetch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import CodedSymbols, Sketch, StreamDecoder
from repro.core.hashing import siphash24

REC_BYTES = 16


@dataclasses.dataclass
class SyncReport:
    symbols_used: int
    symbol_bytes: int
    chunks_fetched: int
    chunk_bytes: int
    naive_bytes: int       # cost of downloading the full store

    @property
    def total_bytes(self):
        return self.symbol_bytes + self.chunk_bytes

    @property
    def savings(self):
        return self.naive_bytes / max(self.total_bytes, 1)


class PeerEndpoint:
    """The healthy side: serves coded symbols + chunk bodies.

    The symbol cache is universal and incremental: it is extended on demand
    and reused across every syncing replica; when the store changes, the
    cache is *updated* (add/remove the delta records) instead of rebuilt —
    the paper's linearity property."""

    def __init__(self, store):
        self.store = store
        self._sketch = Sketch.from_items(store.records(), REC_BYTES)
        self._cid_by_key = {}
        for cid in store.manifest()["chunks"]:
            kh = _cid_hash(cid)
            self._cid_by_key[kh] = cid

    def symbols(self, lo: int, hi: int) -> CodedSymbols:
        sym = self._sketch.symbols(hi)
        return CodedSymbols(sym.sums[lo:], sym.checks[lo:], sym.counts[lo:],
                            REC_BYTES)

    def fetch_chunk(self, cid: str) -> bytes:
        with open(self.store._chunk_path(cid), "rb") as f:
            return f.read()

    def notify_update(self, added: np.ndarray, removed: np.ndarray):
        """Store changed: update the universal symbol cache in place."""
        if len(added):
            self._sketch.add_items(added)
        if len(removed):
            self._sketch.remove_items(removed)


def _cid_hash(cid: str) -> int:
    return int(siphash24(np.frombuffer(
        cid.encode().ljust(64, b"\0")[:64], np.uint8)
        .view(np.uint32)[None, :])[0])


def sync_from_peer(store, peer: PeerEndpoint, block: int = 16,
                   max_m: int = 1 << 20) -> SyncReport:
    """Repair `store` to match `peer.store`.  Returns transfer accounting."""
    local = Sketch.from_items(store.records(), REC_BYTES)
    dec = StreamDecoder(REC_BYTES, local=local)
    m = 0
    step = block
    while not dec.decoded:
        dec.receive(peer.symbols(m, m + step))
        m += step
        step = max(block, m // 2)
        if m > max_m:
            raise RuntimeError("reconciliation did not converge")
    only_peer, only_local = dec.result()  # records A∖B (need) and B∖A (stale)
    man = store.manifest()
    peer_man = peer.store.manifest()
    # map recovered records back to chunk ids via the key-hash half
    fetched = 0
    fetched_bytes = 0
    for rec in only_peer:
        kh = int(rec.view(np.uint64)[0]) if rec.dtype == np.uint32 else 0
        raw = np.ascontiguousarray(rec).view(np.uint8)
        kh = int(np.frombuffer(raw[:8].tobytes(), np.uint64)[0])
        cid = peer._cid_by_key.get(kh)
        if cid is None:
            continue
        data = peer.fetch_chunk(cid)
        with open(store._chunk_path(cid), "wb") as f:
            f.write(data)
        man["chunks"][cid] = peer_man["chunks"][cid]
        fetched += 1
        fetched_bytes += len(data)
    # records only in the stale store = chunks that no longer exist upstream
    for rec in only_local:
        raw = np.ascontiguousarray(rec).view(np.uint8)
        kh = int(np.frombuffer(raw[:8].tobytes(), np.uint64)[0])
        for cid, dig in list(man["chunks"].items()):
            if _cid_hash(cid) == kh and cid not in peer_man["chunks"]:
                del man["chunks"][cid]
    man["leaves"] = peer_man["leaves"]
    man["step"] = peer_man["step"]
    import json, os
    with open(os.path.join(store.root, "manifest.json"), "w") as f:
        json.dump(man, f)
    dec_m = dec.decoded_at
    naive = sum(len(peer.fetch_chunk(cid)) for cid in peer_man["chunks"])
    return SyncReport(symbols_used=dec_m,
                      symbol_bytes=dec_m * (REC_BYTES + 8 + 1),
                      chunks_fetched=fetched, chunk_bytes=fetched_bytes,
                      naive_bytes=naive)
