"""Checkpointing with content-addressed chunks — the substrate the paper's
technique synchronizes.

A checkpoint is stored as fixed-size chunks keyed by (leaf path, chunk idx)
plus a manifest of keyed digests.  Properties that matter at fleet scale:

* **Elastic restore** — chunks are addressed by logical position, not by
  device, so a checkpoint written on any mesh restores onto any other.
* **Reconciliation-ready** — the manifest is a *set* of fixed-length records
  (key-hash ‖ chunk-digest), exactly the shape Rateless IBLT reconciles;
  `checkpoint/reconcile.py` repairs a stale/corrupt store by streaming
  coded symbols from a peer instead of re-downloading everything.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct

import numpy as np
import jax

from repro.core.hashing import siphash24

CHUNK_BYTES = 1 << 18  # 256 KiB


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


def _chunks_of(arr: np.ndarray):
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    for i in range(0, max(len(raw), 1), CHUNK_BYTES):
        yield i // CHUNK_BYTES, raw[i:i + CHUNK_BYTES]


def _digest(key_name: str, idx: int, data: np.ndarray) -> int:
    # chunk bodies are hashed with blake2b (C speed on 256 KiB blobs; the
    # vectorized SipHash is for many short set items, not one long blob)
    h = hashlib.blake2b(np.ascontiguousarray(data).tobytes(),
                        digest_size=8,
                        key=(key_name + f"#{idx}").encode()[:64])
    return int.from_bytes(h.digest(), "little")


class CheckpointStore:
    """Directory layout: manifest.json + chunks/<leafname>#<idx>.bin."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)

    # -- write -------------------------------------------------------------
    def save(self, step: int, tree) -> dict:
        leaves, _ = _leaf_paths(tree)
        manifest = {"step": step, "chunks": {}, "leaves": {}}
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            manifest["leaves"][name] = {"shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
            for idx, data in _chunks_of(arr):
                cid = f"{name}#{idx}"
                manifest["chunks"][cid] = _digest(name, idx, data)
                with open(self._chunk_path(cid), "wb") as f:
                    f.write(data.tobytes())
        with open(os.path.join(self.root, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        return manifest

    def _chunk_path(self, cid: str) -> str:
        return os.path.join(self.root, "chunks",
                            cid.replace("/", "_") + ".bin")

    # -- read ---------------------------------------------------------------
    def manifest(self) -> dict | None:
        path = os.path.join(self.root, "manifest.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def load_leaf(self, name: str, info: dict) -> np.ndarray:
        import math
        dtype = np.dtype(info["dtype"] if info["dtype"] != "bfloat16"
                         else np.uint16)
        nbytes = int(np.prod(info["shape"]) or 1) * dtype.itemsize
        if info["dtype"] == "bfloat16":
            nbytes = int(np.prod(info["shape"]) or 1) * 2
        raw = bytearray()
        idx = 0
        while len(raw) < nbytes:
            with open(self._chunk_path(f"{name}#{idx}"), "rb") as f:
                raw.extend(f.read())
            idx += 1
        arr = np.frombuffer(bytes(raw[:nbytes]), dtype=np.uint8)
        import jax.numpy as jnp
        out = jnp.asarray(arr).view(jnp.dtype(info["dtype"]))
        return out.reshape(info["shape"])

    def restore(self, tree_struct) -> object:
        """Restore into any pytree structure with matching leaf names —
        elastic: the target mesh/device layout is irrelevant because chunks
        are logically addressed."""
        man = self.manifest()
        assert man is not None, "no checkpoint present"
        leaves, treedef = _leaf_paths(tree_struct)
        out = []
        for name, leaf in leaves:
            info = man["leaves"][name]
            arr = self.load_leaf(name, info)
            assert list(arr.shape) == list(info["shape"])
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    def verify(self) -> list[str]:
        """Return chunk ids whose on-disk bytes mismatch the manifest
        (detects corruption / torn writes after a crash)."""
        man = self.manifest()
        bad = []
        for cid, dig in man["chunks"].items():
            name, idx = cid.rsplit("#", 1)
            try:
                with open(self._chunk_path(cid), "rb") as f:
                    data = np.frombuffer(f.read(), np.uint8)
                if _digest(name, int(idx), data) != dig:
                    bad.append(cid)
            except FileNotFoundError:
                bad.append(cid)
        return bad

    # -- reconciliation records ---------------------------------------------
    def records(self) -> np.ndarray:
        """Manifest as fixed-length set items: 8B key-hash ‖ 8B digest ‖
        8B step-invariant salt — the set Rateless IBLT reconciles."""
        man = self.manifest()
        recs = []
        for cid, dig in sorted(man["chunks"].items()):
            kh = siphash24(np.frombuffer(cid.encode().ljust(64, b"\0")[:64],
                                         np.uint8).view(np.uint32)[None, :])
            recs.append(struct.pack("<QQ", int(kh[0]), dig & (2**64 - 1)))
        return np.frombuffer(b"".join(recs), np.uint8).reshape(-1, 16)
