"""GQA attention: global/local (sliding window), causal train/prefill paths
and a KV-cache decode step; optional qk-norm; cross-attention for enc-dec.

KV heads shard over "model" only when divisible by the axis size; otherwise
K/V are computed replicated across model shards (cheap: kv·dh ≪ d) while Q
heads stay model-sharded — the standard GQA/MQA compromise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _init, apply_rope, init_rmsnorm, rmsnorm

NEG_INF = -1e30


def init_attention(key, cfg, dtype, fsdp: bool, model_axis: int = 16):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    row = "data" if fsdp else None
    kv_shard = "model" if kv % model_axis == 0 else None
    p = {"wq": _init(k1, (d, h * dh), dtype=dtype),
         "wk": _init(k2, (d, kv * dh), dtype=dtype),
         "wv": _init(k3, (d, kv * dh), dtype=dtype),
         "wo": _init(k4, (h * dh, d), dtype=dtype)}
    s = {"wq": P(row, "model"), "wk": P(row, kv_shard),
         "wv": P(row, kv_shard), "wo": P("model", row)}
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"], s["k_norm"] = init_rmsnorm(dh, dtype)
    return p, s


def _qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, dh)
    k = (x @ p["wk"]).reshape(B, S, kv, dh)
    v = (x @ p["wv"]).reshape(B, S, kv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep):
    """q: (B,S,H,Dh), k/v: (B,T,KV,Dh); mask: (S,T) or (B,S,T) additive."""
    B, S, H, Dh = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    q = q.reshape(B, S, KV, n_rep, Dh)
    scores = jnp.einsum("bsgrd,btgd->bgrst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(Dh).astype(jnp.float32)
    scores = scores + mask[..., None, None, :, :] if mask.ndim == 2 else \
        scores + mask[:, None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v)
    return out.reshape(B, S, H, Dh)


def causal_mask(S, T, window: int = 0, offset: int = 0):
    """(S, T) additive mask; rows are query positions offset..offset+S-1."""
    qpos = jnp.arange(S) + offset
    kpos = jnp.arange(T)
    ok = kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_flash(q, k, v, n_rep, window: int = 0,
                qblock: int = 512, kblock: int = 1024, mesh_axes=None):
    """Causal attention with nested KV-block scan + online softmax.

    O(S·kblock) live scores instead of O(S²) — the memory-roofline fix for
    the 4k-train / 32k-prefill cells (see EXPERIMENTS.md §Perf: the naive
    path is kept behind REPRO_ATTN=naive as the recorded "before").

    Both scan bodies are jax.checkpoint-ed so AD saves only the O(S·Dh)
    per-step carries instead of every block's (qb,kb) score matrix, and the
    block tensors carry explicit sharding constraints (batch over the data
    axes, heads over "model" when divisible) so GSPMD cannot drop the batch
    sharding inside the loops.  q: (B,S,H,Dh), k/v: (B,T,KV,Dh).
    """
    B, S, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    qb = min(qblock, S)
    kb = min(kblock, T)
    nq, nk = S // qb, T // kb
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)

    def constrain(x, spec):
        if mesh_axes is None:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh_axes["mesh"], spec))

    if mesh_axes is not None:
        da = mesh_axes["data"]
        msz = mesh_axes["model_size"]
        kv_shard = "model" if KV % msz == 0 else None
        blk_spec = P(None, da, kv_shard, None, None, None)  # stacked q blocks
        kv_spec = P(None, da, kv_shard, None, None)
    # (nq, B, KV, R, qb, Dh) / (nk, B, KV, kb, Dh)
    qr = q.reshape(B, nq, qb, KV, n_rep, Dh).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, kb, KV, Dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kb, KV, Dh).transpose(1, 0, 3, 2, 4)
    if mesh_axes is not None:
        qr = constrain(qr, blk_spec)
        kr = constrain(kr, kv_spec)
        vr = constrain(vr, kv_spec)

    def k_step(carry, ki):
        m, l, acc, qblk, qidx = carry
        kblk, vblk, kidx = ki
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        qpos = qidx * qb + jnp.arange(qb)
        kpos = kidx * kb + jnp.arange(kb)
        ok = kpos[None, :] <= qpos[:, None]
        if window:
            ok &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bgkd->bgrqd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc, qblk, qidx), None

    k_step = jax.checkpoint(k_step)

    def q_step(_, qi):
        qblk, qidx = qi                       # (B,KV,R,qb,Dh), scalar idx
        m0 = jnp.full((B, KV, n_rep, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, n_rep, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, n_rep, qb, Dh), jnp.float32)
        (m, l, acc, _, _), _ = jax.lax.scan(
            k_step, (m0, l0, a0, qblk, qidx),
            (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        if mesh_axes is not None:
            out = constrain(out, P(mesh_axes["data"], kv_shard, None,
                                   None, None))
        return None, out

    q_step = jax.checkpoint(q_step)
    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    # outs: (nq, B, KV, R, qb, Dh) -> (B, S, H, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV, n_rep, Dh)
    return out.reshape(B, S, H, Dh).astype(q.dtype)


def attention(x, p, cfg, window: int = 0, return_kv: bool = False,
              mesh_axes=None):
    """Causal self-attention over a full sequence (train / prefill).

    With return_kv, also returns the decode cache: full (B,S,KV,Dh) for
    global blocks; for windowed blocks a rolling buffer of the last
    `window` positions placed at slot = position %% window.
    """
    import os
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(x, p, cfg, positions)
    if os.environ.get("REPRO_ATTN") == "naive" or S <= 512:
        mask = causal_mask(S, S, window)
        out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    else:
        out = _sdpa_flash(q, k, v, cfg.n_heads // cfg.n_kv_heads,
                          window=window, mesh_axes=mesh_axes)
    y = out.reshape(B, S, -1) @ p["wo"]
    if not return_kv:
        return y
    if window and window < S:
        w = window
        ck = jnp.roll(k[:, -w:], shift=S % w, axis=1)
        cv = jnp.roll(v[:, -w:], shift=S % w, axis=1)
    else:
        ck, cv = k, v
    return y, ck, cv


def attention_decode(x, p, cfg, cache_k, cache_v, pos, window: int = 0):
    """One-token decode against a KV cache.

    x: (B, 1, D); cache_k/v: (B, S_max, KV, Dh) — for windowed blocks the
    cache is a rolling buffer of size `window` written at pos % window.
    pos: (B,) current absolute position.
    Returns (out (B,1,D), cache_k, cache_v).
    """
    B = x.shape[0]
    S_max = cache_k.shape[1]
    q, k, v = _qkv(x, p, cfg, pos[:, None])
    slot = pos % S_max if window else pos            # (B,)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    kpos = jnp.arange(S_max)[None, :]
    if window:
        # rolling buffer: slot j holds absolute position pos - ((pos-j) mod S_max)
        age = (pos[:, None] - kpos) % S_max
        ok = age < jnp.minimum(pos[:, None] + 1, window)
    else:
        ok = kpos <= pos[:, None]
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (B, S_max)
    out = _sdpa(q, cache_k, cache_v, mask[:, None, :],
                cfg.n_heads // cfg.n_kv_heads)
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


# -------------------------------------------------------- cross-attention
def init_cross_attention(key, cfg, dtype, fsdp: bool, model_axis: int = 16):
    return init_attention(key, cfg, dtype, fsdp, model_axis)


def cross_attention(x, p, cfg, enc_k, enc_v):
    """x: (B, S, D) queries; enc_k/v precomputed (B, T, KV, Dh)."""
    B, S, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    T = enc_k.shape[1]
    mask = jnp.zeros((S, T), jnp.float32)
    out = _sdpa(q, enc_k, enc_v, mask, cfg.n_heads // cfg.n_kv_heads)
    return out.reshape(B, S, -1) @ p["wo"]


def encode_kv(enc_out, p, cfg):
    """Precompute cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, kv, dh)
    v = (enc_out @ p["wv"]).reshape(B, T, kv, dh)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return k, v
