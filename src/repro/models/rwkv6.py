"""RWKV-6 "Finch" time-mix block (arXiv:2404.05892) — attention-free with
data-dependent per-channel decay.

Recurrence per head (state S ∈ R^{dk×dv}):
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ (diag(u ⊙ k_t) v_tᵀ + S_{t-1})
with w_t = exp(-exp(wlog_t)) ∈ (0,1) data-dependent (LoRA on the shifted
input), r/k/v projections with token-shift mixing, and bonus u for the
current token.

Training/prefill uses the chunkwise-parallel form (intra-chunk matmuls +
inter-chunk scan over chunk states) so the compiled HLO exposes real GEMMs
to the roofline instead of a length-T scalar loop; decode is the O(1)
recurrence.  Numerics: decays accumulate in log space; the intra-chunk
normalization is bounded by the chunk length (CHUNK=64) — validated against
the naive per-step scan in tests/test_models.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _init, init_rmsnorm, rmsnorm

CHUNK = 64
LORA = 64


def init_rwkv(key, cfg, dtype, fsdp: bool):
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.head_dim
    ks = jax.random.split(key, 12)
    row = "data" if fsdp else None
    p = {
        "wr": _init(ks[0], (d, d), dtype=dtype),
        "wk": _init(ks[1], (d, d), dtype=dtype),
        "wv": _init(ks[2], (d, d), dtype=dtype),
        "wg": _init(ks[3], (d, d), dtype=dtype),
        "wo": _init(ks[4], (d, d), dtype=dtype),
        # data-dependent decay: w = exp(-exp(base + lora))
        "w_base": jnp.zeros((d,), jnp.float32) - 0.5,
        "w_lora_a": _init(ks[5], (d, LORA), dtype=dtype),
        "w_lora_b": _init(ks[6], (LORA, d), scale=0.01, dtype=dtype),
        "u": _init(ks[7], (h, dh), scale=0.5, dtype=jnp.float32),
        # token-shift mix coefficients per projection
        "mu": _init(ks[8], (5, d), scale=0.2, dtype=jnp.float32),
        "ln_x": jnp.ones((d,), dtype),
    }
    s = {
        "wr": P(row, "model"), "wk": P(row, "model"), "wv": P(row, "model"),
        "wg": P(row, "model"), "wo": P("model", row),
        "w_base": P(None), "w_lora_a": P(row, None), "w_lora_b": P(None, row),
        "u": P("model", None), "mu": P(None, None), "ln_x": P(None),
    }
    return p, s


def _projections(x, x_prev, p, cfg):
    """Token-shift mixing + r/k/v/g/decay projections.

    x: (B, S, d); x_prev: (B, S, d) = x shifted right by one (carry-in at
    t=0).  Returns r,k,v,g (B,S,H,dh) and log-decay (B,S,H,dh) (negative).
    """
    B, S, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    mu = p["mu"].astype(x.dtype)
    xs = [x + mu[i] * (x_prev - x) for i in range(5)]
    r = (xs[0] @ p["wr"]).reshape(B, S, h, dh)
    k = (xs[1] @ p["wk"]).reshape(B, S, h, dh)
    v = (xs[2] @ p["wv"]).reshape(B, S, h, dh)
    g = jax.nn.silu(xs[3] @ p["wg"]).reshape(B, S, h, dh)
    wl = (xs[4] @ p["w_lora_a"]) @ p["w_lora_b"]
    wlog = p["w_base"].astype(jnp.float32) + jnp.tanh(wl.astype(jnp.float32))
    logw = -jnp.exp(wlog)                       # log decay ∈ (-inf, 0)
    return r, k, v, g, logw.reshape(B, S, h, dh)


def wkv_chunked(r, k, v, logw, u, state0):
    """Chunkwise-parallel WKV.  r/k/v/logw: (B, S, H, dh); u: (H, dh);
    state0: (B, H, dh, dh).  Returns (o (B,S,H,dh), state (B,H,dh,dh))."""
    B, S, H, dh = r.shape
    C = min(CHUNK, S)
    assert S % C == 0, (S, C)
    n = S // C
    rs = r.reshape(B, n, C, H, dh).astype(jnp.float32)
    ks = k.reshape(B, n, C, H, dh).astype(jnp.float32)
    vs = v.reshape(B, n, C, H, dh).astype(jnp.float32)
    lw = logw.reshape(B, n, C, H, dh).astype(jnp.float32)

    cum = jnp.cumsum(lw, axis=2)                 # logD_t inclusive
    total = cum[:, :, -1]                        # (B, n, H, dh)
    # q̃_t = r_t ⊙ exp(logD_{t-1}) (exclusive); k̃_τ = k_τ ⊙ exp(-logD_τ)
    cum_excl = cum - lw
    # exp(-cum) can reach e^(C·|logw|); 60 keeps fp32 finite while the
    # compensating exp(cum_excl) ≤ 1 keeps products bounded — pairs beyond
    # e^60 of intra-chunk decay contribute ~0 (validated vs naive scan).
    CLAMP = 60.0
    q_t = rs * jnp.exp(cum_excl)
    k_t = ks * jnp.exp(jnp.clip(-cum, -CLAMP, CLAMP))
    # intra-chunk: strict lower-triangular (τ < t)
    att = jnp.einsum("bnthd,bnshd->bnhts", q_t, k_t)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)
    att = att * tri[None, None, None]
    o_intra = jnp.einsum("bnhts,bnshd->bnthd", att, vs)
    # bonus (current token): r·(u ⊙ k) v
    bonus = jnp.einsum("bnthd,bnthd->bnth", rs, u[None, None, None] * ks)
    o_intra = o_intra + bonus[..., None] * vs

    # inter-chunk: scan chunk states
    kv = jnp.einsum("bnshd,bnshe->bnhde",
                    ks * jnp.exp(total[:, :, None] - cum), vs)

    def step(S_prev, inp):
        kv_n, tot_n, q_n = inp                   # (B,H,dh,dh),(B,H,dh),(B,C,H,dh)
        o_carry = jnp.einsum("bthd,bhde->bthe", q_n, S_prev)
        S_new = S_prev * jnp.exp(tot_n)[..., None] + kv_n
        return S_new, o_carry

    state, o_carry = jax.lax.scan(
        step, state0.astype(jnp.float32),
        (kv.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3),
         q_t.transpose(1, 0, 2, 3, 4)))
    o = o_intra + o_carry.transpose(1, 0, 2, 3, 4)
    return o.reshape(B, S, H, dh), state


def wkv_step(r, k, v, logw, u, state):
    """O(1) decode step.  r/k/v/logw: (B, H, dh); state: (B, H, dh, dh)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]            # (B,H,dk,dv)
    o = jnp.einsum("bhd,bhde->bhe", rf, u[None, ..., None] * kv + state)
    state = state * w[..., None] + kv
    return o, state


def rwkv_block(x, p, cfg, shift_in, state0):
    """Full time-mix block over a sequence.

    x: (B, S, d); shift_in: (B, d) carry (last token of previous segment);
    state0: (B, H, dh, dh).  Returns (out, shift_out, state)."""
    B, S, d = x.shape
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _projections(x, x_prev, p, cfg)
    o, state = wkv_chunked(r, k, v, logw, p["u"].astype(jnp.float32), state0)
    o = o.astype(x.dtype) * g
    o = rmsnorm(o.reshape(B, S, d), p["ln_x"], cfg.norm_eps)
    return o @ p["wo"], x[:, -1], state


def init_rwkv_ffn(key, cfg, dtype, fsdp: bool):
    """RWKV channel-mix: token-shifted squared-ReLU MLP with sigmoid gate."""
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    row = "data" if fsdp else None
    p = {"wk": _init(k1, (d, f), dtype=dtype),
         "wv": _init(k2, (f, d), dtype=dtype),
         "wr": _init(k3, (d, d), dtype=dtype),
         "mu": _init(key, (2, d), scale=0.2, dtype=jnp.float32)}
    s = {"wk": P(row, "model"), "wv": P("model", row), "wr": P(row, None),
         "mu": P(None, None)}
    return p, s


def rwkv_ffn(x, p, shift_in):
    """x (B,S,d); shift_in (B,d).  Returns (out, shift_out)."""
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (x_prev - x)
    xr = x + mu[1] * (x_prev - x)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]


def rwkv_decode(x, p, cfg, shift_in, state):
    """One-token step.  x: (B, 1, d)."""
    B, _, d = x.shape
    r, k, v, g, logw = _projections(x, shift_in[:, None], p, cfg)
    o, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                        p["u"].astype(jnp.float32), state)
    o = o[:, None].astype(x.dtype) * g
    o = rmsnorm(o.reshape(B, 1, d), p["ln_x"], cfg.norm_eps)
    return o @ p["wo"], x[:, 0], state
