from .model import build_model, input_specs, mesh_axes_of

__all__ = ["build_model", "input_specs", "mesh_axes_of"]
