"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = σ(x_t W_r),  i_t = σ(x_t W_i)
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x̃_t)

The block is a "recurrent block": conv1d(width 4) front, RG-LRU core, gated
output — following the Griffin paper.  The linear recurrence is diagonal, so
training/prefill uses jax.lax.associative_scan (parallel, GEMM-free but
HLO-visible); decode carries (h, conv window) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _init

C_CONST = 8.0
CONV_W = 4


def init_rglru(key, cfg, dtype, fsdp: bool):
    d = cfg.d_model
    dr = cfg.d_rnn or d
    ks = jax.random.split(key, 6)
    row = "data" if fsdp else None
    p = {"w_in": _init(ks[0], (d, dr), dtype=dtype),
         "w_gate": _init(ks[1], (d, dr), dtype=dtype),
         "conv": _init(ks[2], (CONV_W, dr), scale=0.5, dtype=dtype),
         "w_r": _init(ks[3], (dr, dr), dtype=dtype),
         "w_i": _init(ks[4], (dr, dr), dtype=dtype),
         "lam": jnp.ones((dr,), jnp.float32) * 0.7,
         "w_out": _init(ks[5], (dr, d), dtype=dtype)}
    s = {"w_in": P(row, "model"), "w_gate": P(row, "model"),
         "conv": P(None, "model"), "w_r": P(None, "model"),
         "w_i": P(None, "model"), "lam": P("model"),
         "w_out": P("model", row)}
    return p, s


def _conv1d(x, w, carry):
    """Causal depthwise conv, width CONV_W.  x: (B,S,dr); carry: (B,W-1,dr)."""
    full = jnp.concatenate([carry, x], axis=1)
    out = sum(full[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    return out, full[:, -(CONV_W - 1):]


def _gates(xc, p):
    r = jax.nn.sigmoid(xc @ p["w_r"])
    i = jax.nn.sigmoid(xc @ p["w_i"])
    log_a = (-C_CONST * jax.nn.softplus(p["lam"].astype(jnp.float32)) *
             r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) *
             (i * xc).astype(jnp.float32))
    return a, gated


def rglru_block(x, p, cfg, conv_carry, h0):
    """x: (B,S,d).  Returns (out, conv_carry, h_last)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xin = x @ p["w_in"]
    xc, conv_carry = _conv1d(xin, p["conv"], conv_carry)
    a, gated = _gates(xc, p)

    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b) pairs
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_seq = jnp.concatenate([h0[:, None] * 0 + 1.0, a], axis=1)
    b_seq = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)
    _, h = jax.lax.associative_scan(combine, (a_seq, b_seq), axis=1)
    h = h[:, 1:]
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, conv_carry, h[:, -1]


def rglru_decode(x, p, cfg, conv_carry, h):
    """One-token step.  x: (B,1,d); h: (B,dr)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xin = x @ p["w_in"]
    xc, conv_carry = _conv1d(xin, p["conv"], conv_carry)
    a, gated = _gates(xc, p)
    h = a[:, 0] * h + gated[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, conv_carry, h
