"""Model assembly: decoder-only / enc-dec / hybrid stacks with a uniform
facade (init, loss, prefill, decode_step) used by the trainer, the serving
engine and the dry-run.

Depth is organized as repeated *periods* of cfg.block_pattern (e.g.
("rglru","rglru","local") for RecurrentGemma); parameters of each period
are stacked over the period count and the stack is traversed with
jax.lax.scan (+ optional jax.checkpoint) so the compiled HLO stays
one-period-sized regardless of depth — essential for 61/88-layer dry-runs.
Leftover layers (depth % period) run unrolled as the "tail".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as att
from . import moe as moe_mod
from . import rglru as rg
from . import rwkv6 as rwkv
from .layers import (embed_lookup, init_embed, init_mlp, init_rmsnorm, mlp,
                     rmsnorm, unembed, _init)


def shard_aware_ce(logits, labels, mesh_axes):
    """Cross entropy that keeps the (B,S,V) logits sharded over "model".

    take_along_axis over a sharded vocab axis makes GSPMD all-gather the
    full fp32 logits (tens of GB at 4k×256 batch); instead constrain the
    sharding explicitly and select the gold logit with an iota compare —
    both the logsumexp reduction and the masked select then lower to a
    per-shard reduce + small psum.  labels < 0 are masked."""
    from jax.sharding import NamedSharding
    mesh = mesh_axes["mesh"]
    spec = P(mesh_axes["data"], None, "model")
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(mesh, spec))
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1])
    sel = vocab_iota[None, None, :] == labels[..., None]
    gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------- blocks --
def init_block(key, kind, cfg, dtype, fsdp, model_axis):
    """One sub-block's params+specs: pre-norms + mixer (+ffn)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_rmsnorm(cfg.d_model, dtype)
    if kind in ("attn", "local"):
        p["attn"], s["attn"] = att.init_attention(k1, cfg, dtype, fsdp,
                                                  model_axis)
    elif kind == "wkv":
        p["attn"], s["attn"] = rwkv.init_rwkv(k1, cfg, dtype, fsdp)
    elif kind == "rglru":
        p["attn"], s["attn"] = rg.init_rglru(k1, cfg, dtype, fsdp)
    else:
        raise ValueError(kind)
    p["ln2"], s["ln2"] = init_rmsnorm(cfg.d_model, dtype)
    if kind == "wkv":
        p["ffn"], s["ffn"] = rwkv.init_rwkv_ffn(k2, cfg, dtype, fsdp)
    elif cfg.n_experts:
        p["ffn"], s["ffn"] = moe_mod.init_moe(k2, cfg, dtype, fsdp)
    else:
        p["ffn"], s["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, fsdp)
    return p, s


def block_cache_spec(kind, cfg, B, S_ctx, dtype, data_axes, model_axis_size):
    """Decode-state ShapeDtypeStructs (+ pspecs) for one sub-block.

    KV caches shard batch over the data axes and head_dim over "model"
    (every assigned arch has head_dim % 16 == 0; GQA kv-head counts are
    not divisible by the model axis, head_dim is) — decode then psums the
    (tiny) per-token score partials instead of replicating the cache."""
    dh, kv, h = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads
    dr = cfg.d_rnn or cfg.d_model
    dh_shard = "model" if dh % model_axis_size == 0 else None
    if kind in ("attn", "local"):
        w = S_ctx if kind == "attn" else min(cfg.window or S_ctx, S_ctx)
        shp = (B, w, kv, dh)
        return ({"k": jax.ShapeDtypeStruct(shp, dtype),
                 "v": jax.ShapeDtypeStruct(shp, dtype)},
                {"k": P(data_axes, None, None, dh_shard),
                 "v": P(data_axes, None, None, dh_shard)})
    if kind == "wkv":
        return ({"state": jax.ShapeDtypeStruct((B, h, dh, dh), jnp.float32),
                 "shift_a": jax.ShapeDtypeStruct((B, cfg.d_model), dtype),
                 "shift_f": jax.ShapeDtypeStruct((B, cfg.d_model), dtype)},
                {"state": P(data_axes, "model", None, None),
                 "shift_a": P(data_axes, None),
                 "shift_f": P(data_axes, None)})
    if kind == "rglru":
        return ({"h": jax.ShapeDtypeStruct((B, dr), jnp.float32),
                 "conv": jax.ShapeDtypeStruct((B, rg.CONV_W - 1, dr), dtype)},
                {"h": P(data_axes, "model"),
                 "conv": P(data_axes, None, "model")})
    raise ValueError(kind)


def block_forward(x, p, kind, cfg, mesh_axes, state=None):
    """Full-sequence pass.  Returns (x_out, new_state, aux_loss)."""
    aux = 0.0
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_state = {}
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        o, ck, cv = att.attention(h, p["attn"], cfg, window=window,
                                  return_kv=True, mesh_axes=mesh_axes)
        new_state.update(k=ck, v=cv)  # DCE'd when the caller drops states
    elif kind == "wkv":
        st = state or {}
        B = x.shape[0]
        shift = st.get("shift_a", jnp.zeros((B, cfg.d_model), x.dtype))
        s0 = st.get("state", jnp.zeros((B, cfg.n_heads, cfg.head_dim,
                                        cfg.head_dim), jnp.float32))
        o, shift_out, s_new = rwkv.rwkv_block(h, p["attn"], cfg, shift, s0)
        new_state.update(state=s_new, shift_a=shift_out)
    elif kind == "rglru":
        st = state or {}
        B = x.shape[0]
        dr = cfg.d_rnn or cfg.d_model
        conv = st.get("conv", jnp.zeros((B, rg.CONV_W - 1, dr), x.dtype))
        h0 = st.get("h", jnp.zeros((B, dr), jnp.float32))
        o, conv, hl = rg.rglru_block(h, p["attn"], cfg, conv, h0)
        new_state.update(h=hl, conv=conv)
    x = x + o
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "wkv":
        B = x.shape[0]
        shift = (state or {}).get("shift_f",
                                  jnp.zeros((B, cfg.d_model), x.dtype))
        o, shift_out = rwkv.rwkv_ffn(h, p["ffn"], shift)
        new_state["shift_f"] = shift_out
    elif cfg.n_experts:
        o, aux = moe_mod.moe_ffn(h, p["ffn"], cfg, mesh_axes)
    else:
        o = mlp(h, p["ffn"])
    return x + o, new_state, aux


def block_decode(x, p, kind, cfg, mesh_axes, cache, pos):
    """One-token step.  Returns (x_out, new_cache)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    nc = dict(cache)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        o, ck, cv = att.attention_decode(h, p["attn"], cfg, cache["k"],
                                         cache["v"], pos, window=window)
        nc.update(k=ck, v=cv)
    elif kind == "wkv":
        o, shift, st = rwkv.rwkv_decode(h, p["attn"], cfg, cache["shift_a"],
                                        cache["state"])
        nc.update(state=st, shift_a=shift)
    elif kind == "rglru":
        o, conv, hh = rg.rglru_decode(h, p["attn"], cfg, cache["conv"],
                                      cache["h"])
        nc.update(conv=conv, h=hh)
    x = x + o
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "wkv":
        o, shift = rwkv.rwkv_ffn(h, p["ffn"], cache["shift_f"])
        nc["shift_f"] = shift
    elif cfg.n_experts:
        o, _ = moe_mod.moe_ffn(h, p["ffn"], cfg, mesh_axes)
    else:
        o = mlp(h, p["ffn"])
    return x + o, nc


# ---------------------------------------------------------------- model --
class Model:
    """Decoder-only (incl. hybrid/ssm/moe/vlm) language model."""

    def __init__(self, cfg, mesh_axes):
        self.cfg = cfg
        self.mesh_axes = mesh_axes
        pattern = cfg.pattern()
        period = len(cfg.block_pattern)
        self.n_periods = len(pattern) // period
        self.period_kinds = list(cfg.block_pattern)
        self.tail_kinds = pattern[self.n_periods * period:]

    # -- params ----------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ma = self.mesh_axes["model_size"]
        keys = jax.random.split(key, 4)
        p, s = {}, {}
        p["embed"], s["embed"] = init_embed(keys[0], cfg.padded_vocab,
                                            cfg.d_model, dtype, cfg.fsdp)
        if not self.cfg.tie_embeddings:
            p["unembed"], s["unembed"] = init_embed(
                keys[3], cfg.padded_vocab, cfg.d_model, dtype, cfg.fsdp)
        p["ln_f"], s["ln_f"] = init_rmsnorm(cfg.d_model, dtype)

        def stack_periods(key):
            ps, ss = [], None
            for i in range(self.n_periods):
                kk = jax.random.split(jax.random.fold_in(key, i),
                                      len(self.period_kinds))
                bp, bs = zip(*[init_block(kk[j], kind, cfg, dtype, cfg.fsdp, ma)
                               for j, kind in enumerate(self.period_kinds)])
                ps.append(list(bp))
                ss = list(bs)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
            specs = jax.tree.map(
                lambda sp: P(*((None,) + tuple(sp))), ss,
                is_leaf=lambda x: isinstance(x, P))
            return stacked, specs

        p["periods"], s["periods"] = stack_periods(keys[1])
        tail_p, tail_s = [], []
        for i, kind in enumerate(self.tail_kinds):
            bp, bs = init_block(jax.random.fold_in(keys[2], i), kind, cfg,
                                dtype, cfg.fsdp, ma)
            tail_p.append(bp)
            tail_s.append(bs)
        p["tail"], s["tail"] = tail_p, tail_s
        return p, s

    # -- forward ---------------------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"])
        if cfg.frontend == "vision_stub" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        return x

    def _sp_constrain(self, x):
        """Sequence-parallel sharding for the inter-layer residual stream
        (Megatron-SP): the scan-over-periods saves one carry per period for
        the backward pass — L·B·S·d bf16 unsharded over "model" blows the
        HBM budget (e.g. 24 GB for yi-9b train_4k); sharding S (or d) over
        "model" turns that into L·B·S·d/16 with an all-gather at block
        entry and a reduce-scatter at exit, the standard SP trade."""
        ma = self.mesh_axes
        msz = ma["model_size"]
        B, S, d = x.shape
        if S % msz == 0:
            spec = P(ma["data"], "model", None)
        elif d % msz == 0:
            spec = P(ma["data"], None, "model")
        else:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ma["mesh"], spec))

    def _stack(self, params, x, states=None, collect_aux=False):
        cfg = self.cfg
        mesh_axes = self.mesh_axes
        kinds = self.period_kinds

        def period_fn(x, period_params, period_states):
            aux = 0.0
            new_states = []
            for j, kind in enumerate(kinds):
                st = period_states[j] if period_states is not None else None
                x, ns, a = block_forward(x, period_params[j], kind, cfg,
                                         mesh_axes, st)
                aux = aux + a
                new_states.append(ns)
            return self._sp_constrain(x), new_states, aux

        if cfg.remat:
            period_fn = jax.checkpoint(period_fn)

        def scan_body(carry, xs):
            x = carry
            pp, pst = xs
            x, ns, aux = period_fn(x, pp, pst)
            return x, (ns, aux)

        pst = states["periods"] if states is not None else None
        if pst is None:
            empty = [
                {} for _ in kinds]
            pst_xs = None
            x, (new_states, auxs) = jax.lax.scan(
                lambda c, pp: scan_body(c, (pp, [None] * len(kinds))),
                x, params["periods"])
        else:
            x, (new_states, auxs) = jax.lax.scan(scan_body, x,
                                                 (params["periods"], pst))
        aux_total = jnp.sum(auxs) if cfg.n_experts else 0.0
        tail_states = []
        for i, kind in enumerate(self.tail_kinds):
            st = states["tail"][i] if states is not None else None
            x, ns, a = block_forward(x, params["tail"][i], kind, cfg,
                                     mesh_axes, st)
            aux_total = aux_total + a
            tail_states.append(ns)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        out_states = {"periods": new_states, "tail": tail_states}
        return x, out_states, aux_total

    def logits(self, params, x):
        emb = params["embed"] if self.cfg.tie_embeddings else params["unembed"]
        lg = unembed(x, emb)
        if self.cfg.padded_vocab != self.cfg.vocab:  # mask padding rows
            lg = jnp.where(jnp.arange(lg.shape[-1]) < self.cfg.vocab,
                           lg, -1e30)
        return lg

    # -- public API --------------------------------------------------------
    def loss(self, params, batch):
        """Causal LM loss.  labels < 0 are masked."""
        x = self._embed_inputs(params, batch)
        x, _, aux = self._stack(params, x)
        labels = batch["labels"]
        if self.cfg.frontend == "vision_stub" and "patches" in batch:
            npz = batch["patches"].shape[1]
            pad = jnp.full(labels[:, :1].shape, -1, labels.dtype)
            labels = jnp.concatenate(
                [jnp.repeat(pad, npz, axis=1), labels], axis=1)
        logits = self.logits(params, x)
        ce = shard_aware_ce(logits, labels, self.mesh_axes)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    def prefill(self, params, batch):
        """Full forward; returns (last-position logits, decode states).
        KV caches for attn blocks are built by re-running projections is
        wasteful; instead prefill returns hidden states per block via the
        same pass (states carry recurrent blocks; attention caches are
        filled by the serving engine's chunked prefill in serve/engine.py).
        For the dry-run we lower this whole-sequence pass."""
        x = self._embed_inputs(params, batch)
        x, states, _ = self._stack(params, x)
        return self.logits(params, x[:, -1:]), states

    def decode_step(self, params, tokens, caches, pos):
        """tokens (B,1), pos (B,) -> (logits (B,1,V), new caches)."""
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)
        kinds = self.period_kinds

        def scan_body(carry, xs):
            x = carry
            pp, pc = xs
            ncs = []
            for j, kind in enumerate(kinds):
                x, nc = block_decode(x, pp[j], kind, cfg, self.mesh_axes,
                                     pc[j], pos)
                ncs.append(nc)
            return x, ncs

        x, new_caches = jax.lax.scan(scan_body, x,
                                     (params["periods"], caches["periods"]))
        tail_caches = []
        for i, kind in enumerate(self.tail_kinds):
            x, nc = block_decode(x, params["tail"][i], kind, cfg,
                                 self.mesh_axes, caches["tail"][i], pos)
            tail_caches.append(nc)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return self.logits(params, x), {"periods": new_caches,
                                        "tail": tail_caches}

    # -- specs -------------------------------------------------------------
    def cache_spec(self, B, S_ctx):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        data_axes = self.mesh_axes["data"] if \
            B % self.mesh_axes["data_size"] == 0 else None
        msz = self.mesh_axes["model_size"]
        per_kind = [block_cache_spec(k, cfg, B, S_ctx, dtype, data_axes, msz)
                    for k in self.period_kinds]

        def stack_struct(sd):
            return jax.tree.map(
                lambda t: jax.ShapeDtypeStruct((self.n_periods,) + t.shape,
                                               t.dtype), sd)

        def stack_spec(sp):
            return jax.tree.map(lambda q: P(*((None,) + tuple(q))), sp,
                                is_leaf=lambda x: isinstance(x, P))

        periods_struct = [stack_struct(sd) for sd, _ in per_kind]
        periods_spec = [stack_spec(sp) for _, sp in per_kind]
        tail = [block_cache_spec(k, cfg, B, S_ctx, dtype, data_axes, msz)
                for k in self.tail_kinds]
        return ({"periods": periods_struct, "tail": [t[0] for t in tail]},
                {"periods": periods_spec, "tail": [t[1] for t in tail]})
