"""Mixture-of-Experts FFN with two production dispatch modes.

``ep`` — expert parallelism: experts sharded over the "model" mesh axis;
GShard-style capacity-bucketed dispatch with `all_to_all` inside shard_map.
Each data shard builds an (E, C, d) send buffer (C = local capacity per
expert, token dropping beyond), all_to_all splits the E axis across model
shards and returns a per-local-expert buffer of every sender's bucket; a
dense grouped einsum applies the local experts; the reverse all_to_all +
combine weights restore token order.  Collective cost: 2 × all_to_all of
activations — the term §Roofline attributes to MoE cells.

``tp`` — tensor parallelism: every expert's d_ff is sliced over "model"
(weights (E, d, F/16) per shard), tokens stay data-local, top-k dispatch is
a sorted gather + `jax.lax.ragged_dot` grouped GEMM, and the FFN output is
psum-reduced like a dense layer.  No token dropping (dropless); higher
weight-memory traffic under FSDP.  Kept as the §Perf comparison point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _init

def _resolve_shard_map():
    """jax moved shard_map from jax.experimental to the top level and later
    renamed check_rep -> check_vma; pick whichever this jax speaks."""
    import inspect
    if hasattr(jax, "shard_map"):
        impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as impl
    params = inspect.signature(impl).parameters
    flag = "check_vma" if "check_vma" in params else "check_rep"
    return functools.partial(impl, **{flag: False})


_shard_map = _resolve_shard_map()


def _axis_size(axis) -> int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)  # constant-folds to a static int on old jax


def init_moe(key, cfg, dtype, fsdp: bool):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    row = "data" if fsdp else None
    p = {"router": _init(k1, (d, e), scale=0.02, dtype=jnp.float32),
         "wi": _init(k2, (e, d, f), dtype=dtype),
         "wg": _init(k3, (e, d, f), dtype=dtype),
         "wo": _init(k4, (e, f, d), dtype=dtype)}
    if cfg.moe_mode == "ep":
        s = {"router": P(row, None),
             "wi": P("model", row, None), "wg": P("model", row, None),
             "wo": P("model", row, None)}
    else:  # tp: slice d_ff
        s = {"router": P(row, None),
             "wi": P(None, row, "model"), "wg": P(None, row, "model"),
             "wo": P(None, "model", row)}
    return p, s


def _route(x2d, router, k):
    """x2d (T, d) -> (weights (T, k), experts (T, k), aux_loss)."""
    logits = x2d.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # load-balance auxiliary loss (Switch-style)
    e = router.shape[1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return w, idx, aux


# ------------------------------------------------------------------- EP --
def _ep_ffn_local(x2d, router, wi, wg, wo, *, k, cf, axis):
    """Runs inside shard_map: x2d (T_loc, d); wi/wg/wo local expert slices
    (E_loc, d, f).  Experts are sharded over mesh axis `axis`."""
    n_shards = _axis_size(axis)
    T, d = x2d.shape
    e_loc = wi.shape[0]
    E = e_loc * n_shards
    w, idx, aux = _route(x2d, router, k)

    cap = int(max(8, round(cf * T * k / E)))
    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    # position of each (token, expert) pair within its expert bucket
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (T*k, E)
    pos_in_e = jnp.cumsum(one_hot, axis=0) * one_hot - 1
    pos = jnp.max(pos_in_e, axis=1)                            # (T*k,)
    keep = pos < cap                                           # token dropping
    # send buffer (E, cap, d)
    send = jnp.zeros((E, cap, d), x2d.dtype)
    send = send.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], x2d[flat_t], 0))
    # all_to_all: split E across shards, gather sender axis
    recv = jax.lax.all_to_all(send.reshape(n_shards, e_loc, cap, d),
                              axis, split_axis=0, concat_axis=0,
                              tiled=False)           # (n_shards, e_loc, cap, d)
    toks = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_shards * cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", toks, wi)) * \
        jnp.einsum("ecd,edf->ecf", toks, wg)
    out = jnp.einsum("ecf,efd->ecd", h, wo)          # (e_loc, n_shards*cap, d)
    back = out.reshape(e_loc, n_shards, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0,
                             tiled=False).reshape(E, cap, d)
    # combine: gather each kept pair's output, weight, and sum per token
    gathered = ret[flat_e, jnp.where(keep, pos, 0)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * w.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.zeros_like(x2d).at[flat_t].add(contrib)
    return y, aux  # averaged over data shards by the caller's pmean


def moe_ffn(x, p, cfg, mesh_axes):
    """x (B, S, d) -> (y, aux_loss).  mesh_axes: dict with data/model axis
    names present in the enclosing mesh (see launch/mesh.py)."""
    B, S, d = x.shape
    if cfg.moe_mode == "tp":
        return _moe_ffn_tp(x, p, cfg)
    data_axes = mesh_axes["data"]          # e.g. ("pod", "data") or ("data",)
    model_axis = mesh_axes["model"]
    mesh = mesh_axes["mesh"]
    pspec_x = P(data_axes, None, None)
    pspec_r = P(None, None)
    pspec_w = P("model", None, None)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(pspec_x, pspec_r, pspec_w, pspec_w, pspec_w),
        out_specs=(pspec_x, P()))
    def run(xb, router, wi, wg, wo):
        T = xb.shape[0] * xb.shape[1]
        y, aux = _ep_ffn_local(xb.reshape(T, d), router, wi, wg, wo,
                               k=cfg.experts_per_token,
                               cf=cfg.capacity_factor, axis=model_axis)
        aux = jax.lax.pmean(aux, axis_name=model_axis)
        for ax in (data_axes if isinstance(data_axes, tuple) else (data_axes,)):
            aux = jax.lax.pmean(aux, axis_name=ax)
        return y.reshape(xb.shape), aux

    # FSDP gathering of expert weights happens via the in_specs on the
    # "data" dim being replicated inside shard_map: we re-constrain outside.
    return run(x, p["router"], p["wi"], p["wg"], p["wo"])


def _moe_ffn_tp(x, p, cfg):
    """Dropless sorted ragged_dot path; d_ff sliced over "model" by GSPMD."""
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    w, idx, aux = _route(x2d, p["router"], cfg.experts_per_token)
    k = cfg.experts_per_token
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    xs = x2d[jnp.repeat(jnp.arange(T), k)][order]          # (T*k, d) sorted
    group_sizes = jnp.bincount(flat_e, length=cfg.n_experts).astype(jnp.int32)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["wi"], group_sizes)) * \
        jax.lax.ragged_dot(xs, p["wg"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["wo"], group_sizes)       # (T*k, d)
    y = (ys[inv] * w.reshape(-1)[:, None].astype(ys.dtype))
    y = jnp.sum(y.reshape(T, k, d), axis=1)
    return y.reshape(B, S, d), aux
