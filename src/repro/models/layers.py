"""Shared layers: norms, embeddings, RoPE, gated MLP.

Functional style: params are nested dicts of jnp arrays; each init_* returns
(params, specs) where specs mirrors params with jax.sharding.PartitionSpec
leaves (see sharding/rules.py for the axis conventions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms --
def init_rmsnorm(d, dtype):
    return jnp.ones((d,), dtype), P(None)


def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    p = {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    s = {"w": P(None), "b": P(None)}
    return p, s


def layernorm(x, p, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["w"].astype(jnp.float32) +
            p["b"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------- embeddings --
def init_embed(key, vocab, d, dtype, fsdp: bool):
    emb = _init(key, (vocab, d), scale=0.02, dtype=dtype)
    return emb, P("model", "data" if fsdp else None)


def embed_lookup(emb, tokens):
    return jnp.take(emb, tokens, axis=0)


def unembed(x, emb):
    """Tied unembedding: (B, S, D) x (V, D)^T -> fp32 logits."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      emb.astype(jnp.float32))


# ----------------------------------------------------------------- RoPE --
def rope_frequencies(d_head, theta):
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x, positions, theta):
    """x: (..., S, H, Dh), positions: (..., S)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ gated MLP --
def init_mlp(key, d, d_ff, dtype, fsdp: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    row = "data" if fsdp else None
    p = {"wi": _init(k1, (d, d_ff), dtype=dtype),
         "wg": _init(k2, (d, d_ff), dtype=dtype),
         "wo": _init(k3, (d_ff, d), dtype=dtype)}
    s = {"wi": P(row, "model"), "wg": P(row, "model"), "wo": P("model", row)}
    return p, s


def mlp(x, p):
    h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    return h @ p["wo"]
