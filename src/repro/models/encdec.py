"""Encoder–decoder assembly (whisper-family).

The audio frontend is a stub per the assignment: input_specs() provides
precomputed frame embeddings (B, frames, d_model).  Encoder blocks are
bidirectional; decoder blocks are causal self-attention + cross-attention +
MLP.  Norm/positional flavor is standardized to the zoo's RMSNorm+RoPE
(dims are faithful; see DESIGN.md §7 notes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as att
from .layers import (embed_lookup, init_embed, init_mlp, init_rmsnorm, mlp,
                     rmsnorm, unembed)


def _init_enc_block(key, cfg, dtype, fsdp, ma):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_rmsnorm(cfg.d_model, dtype)
    p["attn"], s["attn"] = att.init_attention(k1, cfg, dtype, fsdp, ma)
    p["ln2"], s["ln2"] = init_rmsnorm(cfg.d_model, dtype)
    p["ffn"], s["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, fsdp)
    return p, s


def _init_dec_block(key, cfg, dtype, fsdp, ma):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = _init_enc_block(key, cfg, dtype, fsdp, ma)
    p["ln_c"], s["ln_c"] = init_rmsnorm(cfg.d_model, dtype)
    p["cross"], s["cross"] = att.init_cross_attention(k3, cfg, dtype, fsdp, ma)
    return p, s


def _enc_block(x, p, cfg):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    from .attention import _qkv, _sdpa
    q, k, v = _qkv(h, p["attn"], cfg, positions)
    mask = jnp.zeros((S, S), jnp.float32)  # bidirectional
    o = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    x = x + o.reshape(B, S, -1) @ p["attn"]["wo"]
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(h, p["ffn"])


def _dec_block(x, p, cfg, enc_kv, mesh_axes=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, ck, cv = att.attention(h, p["attn"], cfg, return_kv=True,
                              mesh_axes=mesh_axes)
    x = x + o
    h = rmsnorm(x, p["ln_c"], cfg.norm_eps)
    x = x + att.cross_attention(h, p["cross"], cfg, *enc_kv)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(h, p["ffn"]), {"k": ck, "v": cv}


def _dec_block_step(x, p, cfg, cache, pos):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, ck, cv = att.attention_decode(h, p["attn"], cfg, cache["k"],
                                     cache["v"], pos)
    x = x + o
    h = rmsnorm(x, p["ln_c"], cfg.norm_eps)
    x = x + att.cross_attention(h, p["cross"], cfg, cache["xk"], cache["xv"])
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    nc = dict(cache)
    nc.update(k=ck, v=cv)
    return x + mlp(h, p["ffn"]), nc


class EncDecModel:
    """Whisper-style: stub audio frames -> encoder -> causal decoder."""

    def __init__(self, cfg, mesh_axes):
        self.cfg = cfg
        self.mesh_axes = mesh_axes

    def _mask(self, lg):
        if self.cfg.padded_vocab != self.cfg.vocab:
            return jnp.where(jnp.arange(lg.shape[-1]) < self.cfg.vocab,
                             lg, -1e30)
        return lg

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ma = self.mesh_axes["model_size"]
        ks = jax.random.split(key, 4)
        p, s = {}, {}
        p["embed"], s["embed"] = init_embed(ks[0], cfg.padded_vocab,
                                            cfg.d_model, dtype, cfg.fsdp)
        p["ln_f"], s["ln_f"] = init_rmsnorm(cfg.d_model, dtype)

        def stack(key, init_fn, n):
            ps, ss = [], None
            for i in range(n):
                bp, bs = init_fn(jax.random.fold_in(key, i), cfg, dtype,
                                 cfg.fsdp, ma)
                ps.append(bp)
                ss = bs
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
            specs = jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), ss,
                                 is_leaf=lambda x: isinstance(x, P))
            return stacked, specs

        p["enc"], s["enc"] = stack(ks[1], _init_enc_block,
                                   cfg.encoder_layers or cfg.n_layers)
        p["dec"], s["dec"] = stack(ks[2], _init_dec_block, cfg.n_layers)
        return p, s

    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))

        def body(x, lp):
            fn = _enc_block
            if cfg.remat:
                fn = jax.checkpoint(fn, static_argnums=(2,))
            return fn(x, lp, cfg), None

        x, _ = jax.lax.scan(lambda c, lp: body(c, lp), x, params["enc"])
        return x

    def _dec_stack(self, params, x, enc_out):
        cfg = self.cfg

        mesh_axes = self.mesh_axes

        def body(x, lp):
            kv = att.encode_kv(enc_out, lp["cross"], cfg)
            fn = lambda x_, lp_, kv_: _dec_block(x_, lp_, cfg, kv_, mesh_axes)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, cache = fn(x, lp, kv)
            cache.update(xk=kv[0], xv=kv[1])
            return x, cache

        x, caches = jax.lax.scan(body, x, params["dec"])
        return rmsnorm(x, params["ln_f"], cfg.norm_eps), caches

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x = embed_lookup(params["embed"], batch["tokens"])
        x, _ = self._dec_stack(params, x, enc_out)
        logits = self._mask(unembed(x, params["embed"]))
        from .transformer import shard_aware_ce
        ce = shard_aware_ce(logits, batch["labels"], self.mesh_axes)
        return ce, {"ce": ce, "aux": 0.0}

    def prefill(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x = embed_lookup(params["embed"], batch["tokens"])
        x, caches = self._dec_stack(params, x, enc_out)
        logits = self._mask(unembed(x[:, -1:], params["embed"]))
        return logits, caches

    def decode_step(self, params, tokens, caches, pos):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens)

        def body(x, xs):
            lp, cache = xs
            x, nc = _dec_block_step(x, lp, cfg, cache, pos)
            return x, nc

        x, ncaches = jax.lax.scan(body, x, (params["dec"], caches))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return self._mask(unembed(x, params["embed"])), ncaches

    def cache_spec(self, B, S_ctx):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        data_axes = self.mesh_axes["data"] if \
            B % self.mesh_axes["data_size"] == 0 else None
        msz = self.mesh_axes["model_size"]
        dh, kv = cfg.head_dim, cfg.n_kv_heads
        dh_shard = "model" if dh % msz == 0 else None
        L = cfg.n_layers
        F = cfg.encoder_frames
        mk = lambda shp: jax.ShapeDtypeStruct((L,) + shp, dtype)
        sp = lambda: P(None, data_axes, None, None, dh_shard)
        struct = {"k": mk((B, S_ctx, kv, dh)), "v": mk((B, S_ctx, kv, dh)),
                  "xk": mk((B, F, kv, dh)), "xv": mk((B, F, kv, dh))}
        specs = {"k": sp(), "v": sp(), "xk": sp(), "xv": sp()}
        return struct, specs
