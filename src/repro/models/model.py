"""Facade: build a model + abstract input specs for any (arch, shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .encdec import EncDecModel
from .transformer import Model


def mesh_axes_of(mesh):
    """Mesh metadata dict used across model code.

    data axes = all batch-parallel axes (("pod","data") on the multi-pod
    mesh); "model" is the tensor axis."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    data = tuple(n for n in names if n != "model")
    data_size = 1
    for n in data:
        data_size *= sizes[n]
    data = data[0] if len(data) == 1 else data
    return {"mesh": mesh, "data": data, "model": "model",
            "model_size": sizes["model"], "data_size": data_size}


def build_model(cfg, mesh):
    axes = mesh_axes_of(mesh)
    if cfg.family == "encdec":
        return EncDecModel(cfg, axes)
    return Model(cfg, axes)


def input_specs(cfg, shape, mesh):
    """ShapeDtypeStructs + PartitionSpecs for every model input of a cell.

    train  -> {tokens, labels} (+frames/patches)
    prefill-> {tokens} (+frames/patches)
    decode -> {tokens (B,1), pos (B,)} + KV/state caches
    """
    axes = mesh_axes_of(mesh)
    B, S = shape.global_batch, shape.seq_len
    # replicate batch when it cannot shard (e.g. long_500k's B=1)
    data_axes = axes["data"] if B % axes["data_size"] == 0 else None
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    f32 = lambda s: jax.ShapeDtypeStruct(s, jnp.bfloat16)
    batch_spec = P(data_axes, None)

    if shape.kind in ("train", "prefill"):
        S_text = S
        specs, pspecs = {}, {}
        if cfg.frontend == "vision_stub":
            S_text = S - cfg.n_patches
            specs["patches"] = f32((B, cfg.n_patches, cfg.d_model))
            pspecs["patches"] = P(data_axes, None, None)
        if cfg.frontend == "audio_stub":
            specs["frames"] = f32((B, cfg.encoder_frames, cfg.d_model))
            pspecs["frames"] = P(data_axes, None, None)
        specs["tokens"] = tok((B, S_text))
        pspecs["tokens"] = batch_spec
        if shape.kind == "train":
            specs["labels"] = tok((B, S_text))
            pspecs["labels"] = batch_spec
        return specs, pspecs

    # decode: one new token against an S-long context
    model = build_model(cfg, mesh)
    cache_struct, cache_specs = model.cache_spec(B, S)
    specs = {"tokens": tok((B, 1)), "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
             "caches": cache_struct}
    pspecs = {"tokens": batch_spec, "pos": P(data_axes),
              "caches": cache_specs}
    if cfg.frontend == "audio_stub":
        pass  # cross-attention K/V already inside the cache pytree
    return specs, pspecs
