"""Pallas TPU kernel: per-item keyed hash + mapped-index chain.

The paper's encoder hot loop has two parts; this kernel is part 1: for a
block of items compute (a) the SipHash-2-4 checksum, (b) the mapping-PRNG
seed, and (c) the first K skip-sampled mapped indices (§4.2).  Everything is
elementwise over the item lane — shifts, u32 adds, one rsqrt per jump — pure
VPU work with zero cross-lane traffic, which is why the chain generator is a
lane-parallel kernel rather than the Go heap (see DESIGN.md §3).

Layout: items (n, L) uint32 in VMEM blocks of (BN, L); outputs idx (n, K)
int32, checksum (n, 2) uint32 (hi, lo).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.mapping import _jump_j

from .common import checksum_and_seed


def _kernel(items_ref, idx_ref, chk_ref, *, K: int, m: int, nbytes: int,
            key):
    items = items_ref[...]                       # (BN, L) uint32
    chk_hi, chk_lo, h, l = checksum_and_seed(items, key, nbytes)
    chk_ref[...] = jnp.stack([chk_hi, chk_lo], axis=1)
    idx = jnp.zeros(items.shape[0], dtype=jnp.int32)
    cols = []
    for _ in range(K):
        cols.append(idx)
        nidx, h, l = _jump_j(idx, h, l)
        idx = jnp.minimum(nidx, jnp.int32(m))    # saturate; stop overflow
    # single full-block store (per-column ref stores serialize badly)
    idx_ref[...] = jnp.stack(cols, axis=1)


def map_indices(items, *, K: int, m: int, nbytes: int, key,
                block_n: int = 256, interpret: bool = True):
    """items (n, L) uint32 -> (idx (n, K) int32, checksum (n, 2) uint32).

    n must be a multiple of block_n (ops.py pads).  ``interpret=True`` runs
    the kernel body op-by-op on CPU (this container) — do not wrap it in
    jit there: XLA-compiling the interpreter's unrolled store sequence takes
    minutes.  On TPU pass interpret=False and jit the caller.
    """
    n, L = items.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    kernel = functools.partial(_kernel, K=K, m=m, nbytes=nbytes, key=key)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, L), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_n, K), lambda i: (i, 0)),
                   pl.BlockSpec((block_n, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, K), jnp.int32),
                   jax.ShapeDtypeStruct((n, 2), jnp.uint32)],
        interpret=interpret,
    )(items)
