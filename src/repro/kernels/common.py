"""Shared device-side hashing for the encode and peel kernels.

Both the mapping kernel (`map_indices`) and the wave-peeling decoder
(`peel`) need the same two keyed hashes of an item block: the SipHash-2-4
checksum (paper §4.3) and the mapping-PRNG seed derived from the tweaked
session key.  Factored here so the encoder and decoder kernels stay
bit-identical by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hashing import map_key, siphash24_pair


def checksum_pair(items, key, nbytes: int):
    """(hi, lo) uint32 checksum of an item block ``(..., L)``."""
    return siphash24_pair(items, key, nbytes)


def checksum_and_seed(items, key, nbytes: int):
    """Checksum + mapping-PRNG seed for a block of items.

    Returns ``(chk_hi, chk_lo, seed_hi, seed_lo)`` uint32 arrays; the seed's
    low word is forced odd so the xorshift64 state is never zero — exactly
    the host-side :func:`repro.core.mapping.map_seeds` contract.
    """
    chk_hi, chk_lo = siphash24_pair(items, key, nbytes)
    seed_hi, seed_lo = siphash24_pair(items, map_key(key), nbytes)
    return chk_hi, chk_lo, seed_hi, seed_lo | jnp.uint32(1)
