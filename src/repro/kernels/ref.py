"""Pure-jnp oracles for the Pallas kernels (no Pallas, no tricks).

XOR-scatter has no native jnp primitive, so the oracle goes through bit
parity: unpack words to bits, segment-sum by target index, mod 2, repack.
Slow but obviously correct; every kernel test compares against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mapping import _jump_j

from .common import checksum_and_seed


def map_indices_ref(items, *, K: int, m: int, nbytes: int, key):
    chk_hi, chk_lo, h, l = checksum_and_seed(items, key, nbytes)
    idx = jnp.zeros(items.shape[0], dtype=jnp.int32)
    msat = jnp.asarray(m, jnp.int32)     # m may be traced (peel stages)
    cols = []
    for _ in range(K):
        cols.append(idx)
        nidx, h, l = _jump_j(idx, h, l)
        idx = jnp.minimum(nidx, msat)
    return jnp.stack(cols, axis=1), jnp.stack([chk_hi, chk_lo], axis=1)


def _unpack_bits(x):
    """(n, W) uint32 -> (n, W*32) int32 of 0/1."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (x[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(x.shape[0], -1).astype(jnp.int32)


def _pack_bits(b, W):
    """(m, W*32) int32 0/1 -> (m, W) uint32."""
    b = b.reshape(b.shape[0], W, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def iblt_encode_ref(items, idxs, chks, *, m: int):
    """XOR-scatter via bit-parity segment sums."""
    n, L = items.shape
    K = idxs.shape[1]
    flat = idxs.reshape(-1)
    valid = (flat < m).astype(jnp.int32)
    rep_items = jnp.repeat(items, K, axis=0)
    rep_chks = jnp.repeat(chks, K, axis=0)
    tgt = jnp.where(flat < m, flat, m)
    bits_i = _unpack_bits(rep_items) * valid[:, None]
    bits_c = _unpack_bits(rep_chks) * valid[:, None]
    seg_i = jax.ops.segment_sum(bits_i, tgt, num_segments=m + 1)[:m]
    seg_c = jax.ops.segment_sum(bits_c, tgt, num_segments=m + 1)[:m]
    counts = jax.ops.segment_sum(valid, tgt, num_segments=m + 1)[:m]
    sums = _pack_bits(seg_i % 2, L)
    checks = _pack_bits(seg_c % 2, 2)
    return sums, checks, counts[:, None]


def iblt_apply_ref(items, idxs, chks, sides, *, m, m_out: int | None = None):
    """Signed XOR-scatter oracle for the peel kernel's chain removal.

    Like :func:`iblt_encode_ref` but counts accumulate ``sides`` (int32,
    0 disables a row) instead of +1, and the segment count ``m_out`` may
    exceed the true ``m`` (rows [m, m_out) stay zero) so the caller can keep
    tile-padded symbol state.  ``m`` may be a traced scalar.
    """
    n, L = items.shape
    K = idxs.shape[1]
    if m_out is None:
        m_out = int(m)
    flat = idxs.reshape(-1)
    valid = (flat < m).astype(jnp.int32)
    rep_items = jnp.repeat(items, K, axis=0)
    rep_chks = jnp.repeat(chks, K, axis=0)
    rep_sides = jnp.repeat(sides.astype(jnp.int32), K)
    tgt = jnp.where(flat < m, flat, m_out)
    bits_i = _unpack_bits(rep_items) * valid[:, None]
    bits_c = _unpack_bits(rep_chks) * valid[:, None]
    seg_i = jax.ops.segment_sum(bits_i, tgt, num_segments=m_out + 1)[:m_out]
    seg_c = jax.ops.segment_sum(bits_c, tgt, num_segments=m_out + 1)[:m_out]
    counts = jax.ops.segment_sum(valid * rep_sides, tgt,
                                 num_segments=m_out + 1)[:m_out]
    sums = _pack_bits(seg_i % 2, L)
    checks = _pack_bits(seg_c % 2, 2)
    return sums, checks, counts[:, None]
