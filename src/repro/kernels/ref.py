"""Pure-jnp oracles for the Pallas kernels (no Pallas, no tricks).

XOR-scatter has no native jnp primitive, so the oracle goes through bit
parity: unpack words to bits, segment-sum by target index, mod 2, repack.
Slow but obviously correct; every kernel test compares against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import map_key, siphash24_pair
from repro.core.mapping import _jump_j


def map_indices_ref(items, *, K: int, m: int, nbytes: int, key):
    chk_hi, chk_lo = siphash24_pair(items, key, nbytes)
    seed_hi, seed_lo = siphash24_pair(items, map_key(key), nbytes)
    seed_lo = seed_lo | jnp.uint32(1)
    idx = jnp.zeros(items.shape[0], dtype=jnp.int32)
    h, l = seed_hi, seed_lo
    cols = []
    for _ in range(K):
        cols.append(idx)
        nidx, h, l = _jump_j(idx, h, l)
        idx = jnp.minimum(nidx, jnp.int32(m))
    return jnp.stack(cols, axis=1), jnp.stack([chk_hi, chk_lo], axis=1)


def _unpack_bits(x):
    """(n, W) uint32 -> (n, W*32) int32 of 0/1."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (x[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(x.shape[0], -1).astype(jnp.int32)


def _pack_bits(b, W):
    """(m, W*32) int32 0/1 -> (m, W) uint32."""
    b = b.reshape(b.shape[0], W, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def iblt_encode_ref(items, idxs, chks, *, m: int):
    """XOR-scatter via bit-parity segment sums."""
    n, L = items.shape
    K = idxs.shape[1]
    flat = idxs.reshape(-1)
    valid = (flat < m).astype(jnp.int32)
    rep_items = jnp.repeat(items, K, axis=0)
    rep_chks = jnp.repeat(chks, K, axis=0)
    tgt = jnp.where(flat < m, flat, m)
    bits_i = _unpack_bits(rep_items) * valid[:, None]
    bits_c = _unpack_bits(rep_chks) * valid[:, None]
    seg_i = jax.ops.segment_sum(bits_i, tgt, num_segments=m + 1)[:m]
    seg_c = jax.ops.segment_sum(bits_c, tgt, num_segments=m + 1)[:m]
    counts = jax.ops.segment_sum(valid, tgt, num_segments=m + 1)[:m]
    sums = _pack_bits(seg_i % 2, L)
    checks = _pack_bits(seg_c % 2, 2)
    return sums, checks, counts[:, None]
