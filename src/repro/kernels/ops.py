"""Public device API: the encoder and decoder pipelines around the kernels.

``encode_device`` (pad → map_indices → iblt_encode) is the TPU-native
counterpart of ``repro.core.encode`` and produces bit-identical coded
symbols; ``decode_device`` (pad → wave peeling, :mod:`kernels.peel`) is the
counterpart of ``repro.core.peel`` and recovers the identical difference.
``interpret=None`` auto-selects: real kernels on TPU, interpret mode on CPU
(where the pure-jnp "ref" engines are used — the Pallas interpreter pays
~10 ms/op; the kernels themselves are validated in tests at small sizes).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import DEFAULT_KEY
from repro.core.mapping import kmax

from .iblt_encode import iblt_encode
from .map_indices import map_indices
from .peel import peel_waves, peel_waves_batched


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_items(items, block_n):
    n = items.shape[0]
    np_ = ((n + block_n - 1) // block_n) * block_n
    if np_ == n:
        return items, n
    pad = jnp.zeros((np_ - n, items.shape[1]), dtype=items.dtype)
    return jnp.concatenate([items, pad], axis=0), n


def encode_device(items, *, m: int, nbytes: int | None = None,
                  key=DEFAULT_KEY, K: int | None = None,
                  block_n: int = 256, block_m: int = 256,
                  interpret: bool | None = None,
                  mapping: str | None = None):
    """items (n, L) uint32 -> (sums (m, L) u32, checks (m, 2) u32,
    counts (m,) i32).  Fixed-shape device encoder (chains truncated at
    kmax(m); see DESIGN.md §3 — truncation probability < 1e-12).

    ``mapping``: "pallas" (map_indices kernel) or "ref" (pure-jnp chain).
    Defaults to pallas on TPU; on CPU-interpret the chain kernel pays the
    interpreter's ~10 ms/op tax over K·~15 sequential ops, so "ref" is the
    default there (the kernel itself is still validated in tests at small
    K).  Both produce identical indices."""
    interpret = _auto_interpret(interpret)
    items = jnp.asarray(items, dtype=jnp.uint32)
    n0 = items.shape[0]
    L = items.shape[1]
    if nbytes is None:
        nbytes = 4 * L
    if K is None:
        K = kmax(m)
    if mapping is None:
        mapping = "ref" if interpret else "pallas"

    def run(items_padded):
        # mask first, map second: pad rows are zero items whose mappings
        # must never be computed into the symbols (idx := m kills a row).
        n_pad = items_padded.shape[0] - n0
        if mapping == "pallas":
            # the kernel needs whole blocks — map everything, mask the pads
            idxs, chks = map_indices(items_padded, K=K, m=m, nbytes=nbytes,
                                     key=key, block_n=block_n,
                                     interpret=interpret)
            if n_pad:
                pad_rows = jnp.arange(items_padded.shape[0]) >= n0
                idxs = jnp.where(pad_rows[:, None], jnp.int32(m), idxs)
        else:
            # the jnp chain has no block constraint — skip pad rows entirely
            from .ref import map_indices_ref
            idxs, chks = map_indices_ref(items_padded[:n0], K=K, m=m,
                                         nbytes=nbytes, key=key)
            if n_pad:
                idxs = jnp.concatenate(
                    [idxs, jnp.full((n_pad, K), m, jnp.int32)])
                chks = jnp.concatenate(
                    [chks, jnp.zeros((n_pad, 2), jnp.uint32)])
        sums, checks, counts = iblt_encode(items_padded, idxs, chks, m=m,
                                           block_m=block_m, block_n=block_n,
                                           interpret=interpret)
        return sums[:m], checks[:m], counts[:m, 0]

    padded, n0 = _pad_items(items, block_n)
    if not interpret:
        # real-TPU path: one fused jit program around both kernels
        run = jax.jit(run)
    return run(padded)


def device_symbols_to_host(sums, checks, counts, nbytes: int):
    """Convert device output to a host CodedSymbols (checks -> uint64)."""
    from repro.core.symbols import CodedSymbols
    # np.array (not asarray): jax arrays convert to read-only views, but
    # CodedSymbols buffers are mutated in place by the host decoders.
    sums = np.array(sums, dtype=np.uint32)
    checks = np.asarray(checks, dtype=np.uint32)
    counts = np.asarray(counts)
    c64 = (checks[:, 0].astype(np.uint64) << np.uint64(32)) | \
        checks[:, 1].astype(np.uint64)
    return CodedSymbols(sums, c64, counts.astype(np.int64), nbytes)


def host_symbols_to_device(sym):
    """CodedSymbols -> (sums (m, L) u32, checks (m, 2) u32, counts (m,) i32),
    the device layout (uint64 checksums split into (hi, lo) word pairs).
    Inverse of :func:`device_symbols_to_host` (tested round-trip)."""
    checks = np.empty((sym.m, 2), np.uint32)
    checks[:, 0] = (sym.checks >> np.uint64(32)).astype(np.uint32)
    checks[:, 1] = (sym.checks & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return (jnp.asarray(sym.sums, jnp.uint32), jnp.asarray(checks),
            jnp.asarray(sym.counts.astype(np.int32)))


class DeviceDecodeResult(NamedTuple):
    """Host-materialized outcome of :func:`decode_device`."""
    items: np.ndarray     # (r, L) uint32 — recovered source symbols
    hashes: np.ndarray    # (r,) uint64   — their checksums
    sides: np.ndarray     # (r,) int8     — +1 remote-only, -1 local-only
    success: bool         # all symbols emptied (difference fully recovered)
    overflow: bool        # max_diff exceeded — decode stopped mid-peel
    rounds: int           # peel waves executed
    residual: object      # CodedSymbols — symbols after all removals


def decode_device(sums, checks, counts, *, nbytes: int, key=DEFAULT_KEY,
                  max_diff: int | None = None, max_rounds: int = 10_000,
                  K: int | None = None, block_n: int = 256,
                  block_m: int = 256, interpret: bool | None = None,
                  kernel: str | None = None) -> DeviceDecodeResult:
    """Wave-peel difference symbols on device (paper §3 decode).

    Inputs are device-layout difference symbols — sums (m, L) uint32,
    checks (m, 2) uint32, counts (m,) int32, e.g. from
    :func:`host_symbols_to_device` or an ``encode_device`` subtraction.

    ``max_diff`` bounds the fixed-shape recovered-item buffers; it defaults
    to the tile-padded prefix length (≥ m), which can never overflow:
    recovering an item permanently empties the symbol it was pure at (the
    item was that symbol's whole content), so even a partial decode
    recovers at most m items.  A tighter bound trades buffer size for a possible
    ``overflow=True`` outcome — the decode stops with the overflowing wave
    unapplied (items/residual cover only the completed waves) and the
    caller should fall back to the host decoder.

    ``kernel``: "pallas" (purity/map/apply kernels) or "ref" (pure jnp).
    Defaults to pallas on TPU, ref on CPU-interpret — same policy and
    rationale as :func:`encode_device`.  On TPU the whole wave loop stages
    into one jit program under ``jax.lax.while_loop``; chains are truncated
    at ``kmax(m)`` like the device encoder (< 1e-12 probability).
    """
    interpret = _auto_interpret(interpret)
    if kernel is None:
        kernel = "ref" if interpret else "pallas"
    sums = jnp.asarray(sums, jnp.uint32)
    m, L = sums.shape
    if nbytes is None:
        nbytes = 4 * L
    if m == 0:
        from repro.core.symbols import CodedSymbols
        return DeviceDecodeResult(
            np.zeros((0, L), np.uint32), np.zeros(0, np.uint64),
            np.zeros(0, np.int8), True, False, 0,
            CodedSymbols.zeros(0, nbytes))
    mp = ((m + block_m - 1) // block_m) * block_m
    # defaults quantize to the tile bucket (mp ≥ m) so a growing stream
    # prefix re-uses one compiled program per bucket
    if K is None:
        K = kmax(mp)
    D = mp if max_diff is None else max(int(max_diff), 1)
    checks = jnp.asarray(checks, jnp.uint32)
    counts = jnp.asarray(counts, jnp.int32)

    def run(sums, checks, counts):
        sums = jnp.pad(sums, ((0, mp - m), (0, 0)))
        checks = jnp.pad(checks, ((0, mp - m), (0, 0)))
        counts = jnp.pad(counts, (0, mp - m))[:, None]
        return peel_waves(sums, checks, counts, m=m, nbytes=nbytes, key=key,
                          max_diff=D, K=K, max_rounds=max_rounds,
                          kernel=kernel, block_m=block_m, block_n=block_n,
                          interpret=interpret,
                          use_while_loop=not interpret)

    if not interpret:
        run = jax.jit(run)
    state, success = run(sums, checks, counts)

    n_rec = int(state.n_rec)
    items = np.asarray(state.rec_items)[:n_rec]
    rchk = np.asarray(state.rec_checks)[:n_rec]
    hashes = (rchk[:, 0].astype(np.uint64) << np.uint64(32)) | \
        rchk[:, 1].astype(np.uint64)
    sides = np.asarray(state.rec_sides)[:n_rec].astype(np.int8)
    residual = device_symbols_to_host(
        state.sums[:m], state.checks[:m], state.counts[:m, 0], nbytes)
    return DeviceDecodeResult(items, hashes, sides, bool(success),
                              bool(state.overflow), int(state.rounds),
                              residual)


class PendingBatchedDecode:
    """An in-flight :func:`decode_device_batched_start` dispatch.

    Holds the device-resident :class:`~repro.kernels.peel.PeelState` (with
    its leading unit axis) before host materialization.  ``ready()`` polls
    the underlying JAX arrays non-blockingly — on TPU the whole wave loop
    is one async dispatch, so a caller can overlap host work (e.g. frame
    ingest for the next round) with the decode and only then ``wait()``.
    On CPU the Python wave loop has already run by construction and
    ``ready()`` is immediately True.
    """

    __slots__ = ("_state", "_success", "_ms", "_nbytes", "_results")

    def __init__(self, state, success, ms, nbytes, results=None):
        self._state = state
        self._success = success
        self._ms = ms
        self._nbytes = nbytes
        self._results = results

    def ready(self) -> bool:
        """Non-blocking: True once the device results can be read without
        stalling (always True for trivially-empty or materialized work)."""
        if self._results is not None:
            return True
        is_ready = getattr(self._success, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else True

    def wait(self) -> list[DeviceDecodeResult]:
        """Materialize (blocking) — one result per input unit, in order."""
        if self._results is not None:
            return self._results
        state, success, ms, nbytes = \
            self._state, self._success, self._ms, self._nbytes
        rec_items = np.asarray(state.rec_items)
        rec_checks = np.asarray(state.rec_checks)
        rec_sides = np.asarray(state.rec_sides)
        n_recs = np.asarray(state.n_rec)
        overflow = np.asarray(state.overflow)
        rounds = np.asarray(state.rounds)
        success = np.asarray(success)
        r_sums = np.asarray(state.sums)
        r_checks = np.asarray(state.checks)
        r_counts = np.asarray(state.counts)

        out = []
        for s, m_s in enumerate(ms):
            n_rec = int(n_recs[s])
            rchk = rec_checks[s, :n_rec]
            hashes = (rchk[:, 0].astype(np.uint64) << np.uint64(32)) | \
                rchk[:, 1].astype(np.uint64)
            residual = device_symbols_to_host(
                r_sums[s, :m_s], r_checks[s, :m_s], r_counts[s, :m_s, 0],
                nbytes)
            out.append(DeviceDecodeResult(
                rec_items[s, :n_rec].copy(), hashes,
                rec_sides[s, :n_rec].astype(np.int8), bool(success[s]),
                bool(overflow[s]), int(rounds[s]), residual))
        self._results = out
        self._state = self._success = None   # free device references
        return out


def decode_device_batched_start(units, *, nbytes: int, key=DEFAULT_KEY,
                                max_diff: int | None = None,
                                max_rounds: int = 10_000, K: int | None = None,
                                block_m: int = 256, pad_units: int | None = None,
                                interpret: bool | None = None
                                ) -> PendingBatchedDecode:
    """Dispatch the batched wave decode of U units without materializing.

    ``units`` is a sequence of host :class:`~repro.core.symbols.CodedSymbols`
    — one ragged residual prefix per unit (the ``work`` buffers of U
    decoders; a unit is a shard of one session or, through the protocol
    engine, any peer×shard pair sharing this shape bucket).  Every unit is
    padded to a single shared tile bucket
    ``mp = ceil(max_u m_u / block_m) · block_m`` and the per-unit true
    prefix lengths travel as a traced ``(U,)`` data vector into
    :func:`repro.kernels.peel.peel_waves_batched`, which ``vmap``s the wave
    engine over the unit axis: one compiled program, one dispatch per wave
    (or one total under ``lax.while_loop`` on TPU), regardless of U.

    ``max_diff`` bounds each unit's fixed recovered-item buffer
    *individually*; a unit that trips it freezes only itself and comes
    back with ``overflow=True`` while its neighbours finish — the caller
    falls back to the host decoder for exactly those units.  The default
    (``mp``) can never overflow, same argument as :func:`decode_device`.

    ``pad_units`` pads the unit axis to a fixed batch size with empty
    (m=0) dummy units, which no-op after their first wave.  The unit
    count is a static shape in the per-bucket jit cache, so a caller
    whose batch shrinks as units settle (the protocol engine, as peers
    terminate) quantizes U to e.g. the next power of two and re-uses one
    compiled program instead of recompiling per departure.

    Returns a :class:`PendingBatchedDecode`; ``wait()`` yields one
    :class:`DeviceDecodeResult` per unit, in input order.
    """
    interpret = _auto_interpret(interpret)
    from repro.core.symbols import CodedSymbols
    U = len(units)
    if U == 0:
        return PendingBatchedDecode(None, None, (), nbytes, results=[])
    ms = [sym.m for sym in units]
    m_hi = max(ms)
    if m_hi == 0:
        L = units[0].L
        empty = DeviceDecodeResult(
            np.zeros((0, L), np.uint32), np.zeros(0, np.uint64),
            np.zeros(0, np.int8), True, False, 0,
            CodedSymbols.zeros(0, nbytes))
        return PendingBatchedDecode(None, None, ms, nbytes,
                                    results=[empty] * U)
    L = units[0].L
    assert all(sym.L == L and sym.nbytes == units[0].nbytes
               for sym in units), "units must share one item geometry"
    Up = max(U, pad_units) if pad_units else U
    mp = ((m_hi + block_m - 1) // block_m) * block_m
    if K is None:
        K = kmax(mp)
    D = mp if max_diff is None else max(int(max_diff), 1)

    sums = np.zeros((Up, mp, L), np.uint32)
    checks = np.zeros((Up, mp, 2), np.uint32)
    counts = np.zeros((Up, mp, 1), np.int32)
    for s, sym in enumerate(units):
        sums[s, : sym.m] = sym.sums
        checks[s, : sym.m, 0] = (sym.checks >> np.uint64(32)).astype(np.uint32)
        checks[s, : sym.m, 1] = (sym.checks &
                                 np.uint64(0xFFFFFFFF)).astype(np.uint32)
        counts[s, : sym.m, 0] = sym.counts.astype(np.int32)

    state, success = peel_waves_batched(
        jnp.asarray(sums), jnp.asarray(checks), jnp.asarray(counts),
        m=np.asarray(ms + [0] * (Up - U), np.int32), nbytes=nbytes, key=key,
        max_diff=D, K=K, max_rounds=max_rounds,
        use_while_loop=not interpret)
    # wait() materializes per entry of ms (length U): dummy pad units past
    # U are simply never read back
    return PendingBatchedDecode(state, success, ms, nbytes)


def decode_device_batched(units, *, nbytes: int, key=DEFAULT_KEY,
                          max_diff: int | None = None,
                          max_rounds: int = 10_000, K: int | None = None,
                          block_m: int = 256, pad_units: int | None = None,
                          interpret: bool | None = None
                          ) -> list[DeviceDecodeResult]:
    """Wave-peel U units' difference symbols in ONE batched device call.

    The synchronous convenience over :func:`decode_device_batched_start` —
    dispatch and immediately materialize.  Callers that can overlap host
    work with the device decode (the protocol engine's double-buffered
    tick loop) use start/``wait`` directly.
    """
    return decode_device_batched_start(
        units, nbytes=nbytes, key=key, max_diff=max_diff,
        max_rounds=max_rounds, K=K, block_m=block_m, pad_units=pad_units,
        interpret=interpret).wait()
