"""Public device-encoder API: pad → map_indices kernel → iblt_encode kernel.

``encode_device`` is the TPU-native counterpart of ``repro.core.encode`` and
produces bit-identical coded symbols (tested in tests/test_kernels.py).
``interpret=None`` auto-selects: real kernels on TPU, interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import DEFAULT_KEY
from repro.core.mapping import kmax

from .iblt_encode import iblt_encode
from .map_indices import map_indices


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_items(items, block_n):
    n = items.shape[0]
    np_ = ((n + block_n - 1) // block_n) * block_n
    if np_ == n:
        return items, n
    pad = jnp.zeros((np_ - n, items.shape[1]), dtype=items.dtype)
    return jnp.concatenate([items, pad], axis=0), n


def encode_device(items, *, m: int, nbytes: int | None = None,
                  key=DEFAULT_KEY, K: int | None = None,
                  block_n: int = 256, block_m: int = 256,
                  interpret: bool | None = None,
                  mapping: str | None = None):
    """items (n, L) uint32 -> (sums (m, L) u32, checks (m, 2) u32,
    counts (m,) i32).  Fixed-shape device encoder (chains truncated at
    kmax(m); see DESIGN.md §3 — truncation probability < 1e-12).

    ``mapping``: "pallas" (map_indices kernel) or "ref" (pure-jnp chain).
    Defaults to pallas on TPU; on CPU-interpret the chain kernel pays the
    interpreter's ~10 ms/op tax over K·~15 sequential ops, so "ref" is the
    default there (the kernel itself is still validated in tests at small
    K).  Both produce identical indices."""
    interpret = _auto_interpret(interpret)
    items = jnp.asarray(items, dtype=jnp.uint32)
    n0 = items.shape[0]
    L = items.shape[1]
    if nbytes is None:
        nbytes = 4 * L
    if K is None:
        K = kmax(m)
    if mapping is None:
        mapping = "ref" if interpret else "pallas"

    def run(items_padded):
        if mapping == "pallas":
            idxs, chks = map_indices(items_padded, K=K, m=m, nbytes=nbytes,
                                     key=key, block_n=block_n,
                                     interpret=interpret)
        else:
            from .ref import map_indices_ref
            idxs, chks = map_indices_ref(items_padded, K=K, m=m,
                                         nbytes=nbytes, key=key)
        if items_padded.shape[0] != n0:
            # padding rows are zero items — kill their mappings (idx := m)
            rows = jnp.arange(items_padded.shape[0]) >= n0
            idxs = jnp.where(rows[:, None], jnp.int32(m), idxs)
        sums, checks, counts = iblt_encode(items_padded, idxs, chks, m=m,
                                           block_m=block_m, block_n=block_n,
                                           interpret=interpret)
        return sums[:m], checks[:m], counts[:m, 0]

    padded, n0 = _pad_items(items, block_n)
    if not interpret:
        # real-TPU path: one fused jit program around both kernels
        run = jax.jit(run)
    return run(padded)


def device_symbols_to_host(sums, checks, counts, nbytes: int):
    """Convert device output to a host CodedSymbols (checks -> uint64)."""
    from repro.core.symbols import CodedSymbols
    sums = np.asarray(sums, dtype=np.uint32)
    checks = np.asarray(checks, dtype=np.uint32)
    counts = np.asarray(counts)
    c64 = (checks[:, 0].astype(np.uint64) << np.uint64(32)) | \
        checks[:, 1].astype(np.uint64)
    return CodedSymbols(sums, c64, counts.astype(np.int64), nbytes)
