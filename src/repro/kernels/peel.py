"""Device-side wave-peeling decoder (paper §3 peeling as dense VPU work).

Host peeling walks a sparse graph one pure symbol at a time; on TPU we
restate each belief-propagation round as three dense, fixed-shape stages:

1. **purity scan** — a tiled Pallas kernel re-keys every coded symbol's sum
   (SipHash-2-4, shared with the encoder via :mod:`kernels.common`) and
   compares it with the stored checksum: ``±1`` where the symbol holds
   exactly one source symbol, ``0`` elsewhere.
2. **compaction + dedupe** — pure rows are gathered into a fixed ``cap``-row
   buffer (``jnp.nonzero(..., size=cap)``), deduped pairwise by checksum
   within the wave and against the already-recovered buffer.  The same item
   being pure at several indices at once is the common case near the end of
   a decode.
3. **chain removal** — recovered items re-derive their mapped-index chains
   with the *encoder's own* ``map_indices`` kernel and are XOR-ed out of
   every position with ``iblt_apply``: the identical (BN items × BM symbols)
   masked XOR-tree of ``iblt_encode``, plus a signed count update
   (``counts -= Σ mask·side``).

The three stages iterate to a fixed point — ``jax.lax.while_loop`` when the
whole program is jitted for TPU, a plain Python loop in eager/interpret
mode on CPU (XLA-compiling the interpreter's op sequence takes minutes; see
the note in ``tests/test_kernels.py``).  Every shape is static: symbols are
padded to ``block_m`` tiles, per-wave compaction holds ``cap`` rows, and the
recovered-item buffer holds ``max_diff`` rows — a wave that would overflow
it leaves the state untouched and raises the ``overflow`` flag so the
caller can fall back to the exact host decoder.

A pure-jnp engine (``kernel="ref"``) mirrors each stage op-for-op for
CPU runs and oracle tests; both engines produce bit-identical waves.

For batched serving, :func:`peel_waves_batched` ``vmap``s the identical
wave over a leading **unit axis** — U independent decodes, ragged prefix
lengths as data, one compiled program (see ``ops.decode_device_batched``).
A unit was originally one shard of a sharded session; through
``repro.protocol.engine`` it is any (peer, shard) pair in a shape bucket,
so N concurrent peers cost one dispatch per tick, not N.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import checksum_pair
from .iblt_encode import _tree_xor
from .map_indices import map_indices
from .ref import iblt_apply_ref, map_indices_ref


# ---------------------------------------------------------------------------
# Stage 1: purity scan.
# ---------------------------------------------------------------------------
def _purity_body(sums, checks, counts, *, key, nbytes: int):
    """(BM, L) sums, (BM, 2) checks, (BM, 1) counts -> (BM,) int32 side.

    ``+1`` / ``-1`` where the symbol is pure (checksum matches the keyed
    hash of its sum and it is non-empty), ``0`` otherwise.
    """
    h_hi, h_lo = checksum_pair(sums, key, nbytes)
    cnt = counts[:, 0]
    pure = (h_hi == checks[:, 0]) & (h_lo == checks[:, 1]) & (cnt != 0)
    side = jnp.where(cnt > 0, jnp.int32(1), jnp.int32(-1))
    return jnp.where(pure, side, jnp.int32(0))


def _purity_kernel(sums_ref, checks_ref, counts_ref, side_ref, *, key,
                   nbytes: int):
    side = _purity_body(sums_ref[...], checks_ref[...], counts_ref[...],
                        key=key, nbytes=nbytes)
    side_ref[...] = side[:, None]


def purity_scan(sums, checks, counts, *, key, nbytes: int,
                block_m: int = 256, interpret: bool = True):
    """Tiled purity test: (mp, ...) symbol arrays -> (mp,) int32 sides.

    mp must be a multiple of block_m (``ops.decode_device`` pads).
    """
    mp, L = sums.shape
    assert mp % block_m == 0, (mp, block_m)
    grid = (mp // block_m,)
    kernel = functools.partial(_purity_kernel, key=key, nbytes=nbytes)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, L), lambda i: (i, 0)),
                  pl.BlockSpec((block_m, 2), lambda i: (i, 0)),
                  pl.BlockSpec((block_m, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.int32),
        interpret=interpret,
    )(sums, checks, counts)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Stage 3: signed dense XOR removal (iblt_encode's tile, plus sides).
# ---------------------------------------------------------------------------
def _apply_kernel(items_ref, idx_ref, chk_ref, side_ref, sums_ref, checks_ref,
                  counts_ref, *, block_m: int, m: int):
    i = pl.program_id(0)   # symbol tile
    j = pl.program_id(1)   # item block (innermost: accumulation)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        checks_ref[...] = jnp.zeros_like(checks_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    items = items_ref[...]          # (BN, L) uint32
    chks = chk_ref[...]             # (BN, 2) uint32
    idxs = idx_ref[...]             # (BN, K) int32
    sides = side_ref[...]           # (BN, 1) int32
    bn, L = items.shape
    base = i * block_m
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, block_m), 1) + base
    eq = (idxs[:, :, None] == lane[:, None, :]) & (idxs[:, :, None] < m)
    mask = jnp.any(eq, axis=1)                         # (BN, BM)
    mask_u = mask.astype(jnp.uint32)
    counts_ref[...] = counts_ref[...] + \
        jnp.sum(mask.astype(jnp.int32) * sides, axis=0)[:, None]
    sums_ref[...] = sums_ref[...] ^ \
        _tree_xor(mask_u[:, :, None] * items[:, None, :])
    checks_ref[...] = checks_ref[...] ^ \
        _tree_xor(mask_u[:, :, None] * chks[:, None, :])


def iblt_apply(items, idxs, chks, sides, *, m: int, block_m: int = 256,
               block_n: int = 256, interpret: bool = True):
    """Signed coded-symbol delta of ``items`` over their mapped chains.

    items (n, L) uint32, idxs (n, K) int32 (pad = m kills a row),
    chks (n, 2) uint32, sides (n,) int32 -> (sums (m', L) uint32,
    checks (m', 2) uint32, counts (m', 1) int32), m' = m rounded up to
    block_m.  The caller XORs the sums/checks delta into its symbol state
    and *subtracts* the counts delta (removal = encode with negated sign).
    """
    n, L = items.shape
    K = idxs.shape[1]
    assert n % block_n == 0
    mp = ((m + block_m - 1) // block_m) * block_m
    grid = (mp // block_m, n // block_n)
    kernel = functools.partial(_apply_kernel, block_m=block_m, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, L), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_n, K), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_n, 2), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_n, 1), lambda i, j: (j, 0))],
        out_specs=[pl.BlockSpec((block_m, L), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_m, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, L), jnp.uint32),
                   jax.ShapeDtypeStruct((mp, 2), jnp.uint32),
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32)],
        interpret=interpret,
    )(items, idxs, chks, sides.astype(jnp.int32)[:, None])


# ---------------------------------------------------------------------------
# The wave loop.
# ---------------------------------------------------------------------------
class PeelState(NamedTuple):
    sums: jax.Array        # (mp, L) uint32 — residual symbol sums
    checks: jax.Array      # (mp, 2) uint32 — residual checksums (hi, lo)
    counts: jax.Array      # (mp, 1) int32  — residual signed counts
    rec_items: jax.Array   # (D, L) uint32  — recovered source symbols
    rec_checks: jax.Array  # (D, 2) uint32  — their checksums
    rec_sides: jax.Array   # (D,) int32     — +1 remote-only, -1 local-only
    n_rec: jax.Array       # () int32
    changed: jax.Array     # () bool — last wave recovered something
    overflow: jax.Array    # () bool — a wave would exceed max_diff
    rounds: jax.Array      # () int32


def _stage1(sums, checks, counts, rec_checks, n_rec, m, *, mp: int, cap: int,
            max_diff: int, purity_fn):
    """Purity scan + pure-row compaction + dedupe.

    Returns ``(p_items, p_chk, p_side, keep, n_new, overflow)`` — the
    wave's recovery candidates in ``cap`` fixed slots.  Pure rows beyond
    ``cap`` simply wait for the next wave (the scan is dense, nothing is
    lost).  ``m`` may be traced; every shape is static.
    """
    side = purity_fn(sums, checks, counts)                     # (mp,) i32
    pidx = jnp.nonzero(side != 0, size=cap, fill_value=mp)[0]
    valid = pidx < mp
    g = jnp.minimum(pidx, mp - 1)
    p_items = jnp.where(valid[:, None], sums[g], jnp.uint32(0))
    p_chk = jnp.where(valid[:, None], checks[g], jnp.uint32(0))
    p_side = jnp.where(valid, side[g], jnp.int32(0))

    # dedupe by checksum: within the wave (first occurrence wins — the same
    # item is often pure at several indices at once) ...
    eq = (p_chk[:, 0][:, None] == p_chk[:, 0][None, :]) & \
         (p_chk[:, 1][:, None] == p_chk[:, 1][None, :]) & \
         valid[:, None] & valid[None, :]
    dup = (jnp.tril(eq.astype(jnp.int32), k=-1) > 0).any(axis=1)
    # ... and against everything recovered in earlier waves
    live = jnp.arange(rec_checks.shape[0]) < n_rec
    seen = ((p_chk[:, 0][:, None] == rec_checks[:, 0][None, :]) &
            (p_chk[:, 1][:, None] == rec_checks[:, 1][None, :]) &
            live[None, :]).any(axis=1)
    keep = valid & ~dup & ~seen
    n_new = jnp.sum(keep.astype(jnp.int32))
    overflow = n_rec + n_new > max_diff
    return p_items, p_chk, p_side, keep, n_new, overflow


def _stage2(state: PeelState, p_items, p_chk, p_side, keep, m, *, mp: int,
            max_diff: int, map_fn, apply_fn) -> PeelState:
    """Chain re-derivation + signed dense removal + recovered-buffer append.

    Flags (``changed``/``overflow``/``rounds``) are managed by the caller.
    """
    n_new = jnp.sum(keep.astype(jnp.int32))
    idxs, _ = map_fn(p_items, m)
    idxs = jnp.where(keep[:, None], idxs, jnp.asarray(m, jnp.int32))
    d_sums, d_checks, d_counts = apply_fn(
        p_items, idxs, p_chk, jnp.where(keep, p_side, jnp.int32(0)), m)

    pos = state.n_rec + jnp.cumsum(keep.astype(jnp.int32)) - 1
    dest = jnp.where(keep, pos, max_diff)          # index max_diff = dropped
    return state._replace(
        sums=state.sums ^ d_sums[:mp],
        checks=state.checks ^ d_checks[:mp],
        counts=state.counts - d_counts[:mp],
        rec_items=state.rec_items.at[dest].set(p_items, mode="drop"),
        rec_checks=state.rec_checks.at[dest].set(p_chk, mode="drop"),
        rec_sides=state.rec_sides.at[dest].set(p_side, mode="drop"),
        n_rec=state.n_rec + n_new,
    )


def _wave(state: PeelState, m, *, mp: int, cap: int, max_diff: int,
          purity_fn, map_fn, apply_fn) -> PeelState:
    """One traced peel wave (the ``lax.while_loop`` body).  On overflow the
    symbol/recovered state is preserved (only the flag changes) so a host
    fallback can redecode from scratch."""
    p_items, p_chk, p_side, keep, n_new, overflow = _stage1(
        state.sums, state.checks, state.counts, state.rec_checks,
        state.n_rec, m, mp=mp, cap=cap, max_diff=max_diff,
        purity_fn=purity_fn)
    out = _stage2(state, p_items, p_chk, p_side, keep, m, mp=mp,
                  max_diff=max_diff, map_fn=map_fn, apply_fn=apply_fn)
    out = out._replace(changed=n_new > 0, overflow=overflow,
                       rounds=state.rounds + 1)
    frozen = state._replace(changed=jnp.array(False), overflow=overflow,
                            rounds=state.rounds + 1)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(overflow, a, b), frozen, out)


def _engines(*, nbytes: int, key, K: int, kernel: str, m: int | None,
             mp: int, block_m: int, block_n: int, interpret: bool):
    """Build (purity_fn, map_fn(items, m), apply_fn(items, idxs, chks,
    sides, m)) for one engine.  The ref engine treats ``m`` as data (so one
    jitted program serves every prefix length within a tile bucket); the
    Pallas engine bakes the static ``m`` into its kernels."""
    if kernel == "pallas":
        purity_fn = functools.partial(purity_scan, key=key, nbytes=nbytes,
                                      block_m=block_m, interpret=interpret)

        def map_fn(items, _m):
            return map_indices(items, K=K, m=m, nbytes=nbytes, key=key,
                               block_n=block_n, interpret=interpret)

        def apply_fn(items, idxs, chks, sides, _m):
            return iblt_apply(items, idxs, chks, sides, m=m,
                              block_m=block_m, block_n=block_n,
                              interpret=interpret)
    else:
        def purity_fn(sums, checks, counts):
            return _purity_body(sums, checks, counts, key=key, nbytes=nbytes)

        def map_fn(items, m):
            return map_indices_ref(items, K=K, m=m, nbytes=nbytes, key=key)

        def apply_fn(items, idxs, chks, sides, m):
            return iblt_apply_ref(items, idxs, chks, sides, m=m, m_out=mp)
    return purity_fn, map_fn, apply_fn


@functools.lru_cache(maxsize=128)
def _ref_stages_jit(mp: int, cap: int, max_diff: int, K: int, L: int,
                    nbytes: int, key):
    """Jitted ref-engine wave stages, cached per static-shape bucket.

    ``m`` enters both stages as a traced scalar, so a growing stream prefix
    re-uses one compiled program until it crosses a tile boundary.
    """
    purity_fn, map_fn, apply_fn = _engines(
        nbytes=nbytes, key=key, K=K, kernel="ref", m=None, mp=mp,
        block_m=mp, block_n=cap, interpret=True)
    s1 = jax.jit(functools.partial(_stage1, mp=mp, cap=cap,
                                   max_diff=max_diff, purity_fn=purity_fn))
    s2 = jax.jit(functools.partial(_stage2, mp=mp, max_diff=max_diff,
                                   map_fn=map_fn, apply_fn=apply_fn))
    return s1, s2


def peel_waves(sums, checks, counts, *, m: int, nbytes: int, key,
               max_diff: int, K: int, max_rounds: int = 10_000,
               kernel: str = "ref", block_m: int = 256, block_n: int = 256,
               interpret: bool = True, use_while_loop: bool = False):
    """Iterate purity → compact/dedupe → remove to a fixed point.

    Inputs are the *padded* difference symbols: sums (mp, L) uint32, checks
    (mp, 2) uint32, counts (mp, 1) int32 with mp a multiple of block_m and
    rows [m, mp) zero.  Returns the final :class:`PeelState` plus a
    ``success`` scalar (all symbols empty — the ρ(0)=1 termination signal
    holds: symbol 0 empties last).

    ``use_while_loop=True`` runs the loop as ``jax.lax.while_loop`` so the
    whole decode stages into one jit program (the TPU path).  Otherwise the
    loop runs in Python: the ref engine's stages are jitted per shape
    bucket (with ``m`` as data), and waves that recover nothing skip the
    removal stage entirely — the common case while a stream decoder is
    still below the decode threshold.
    """
    mp, L = sums.shape
    D = max_diff
    cap = min(2 * max(D, 1), mp)
    cap = max(((cap + block_n - 1) // block_n) * block_n, block_n)
    key = tuple(key)
    state = PeelState(
        sums=jnp.asarray(sums, jnp.uint32),
        checks=jnp.asarray(checks, jnp.uint32),
        counts=jnp.asarray(counts, jnp.int32),
        rec_items=jnp.zeros((D, L), jnp.uint32),
        rec_checks=jnp.zeros((D, 2), jnp.uint32),
        rec_sides=jnp.zeros(D, jnp.int32),
        n_rec=jnp.int32(0),
        changed=jnp.array(True),
        overflow=jnp.array(False),
        rounds=jnp.int32(0),
    )

    if use_while_loop:
        purity_fn, map_fn, apply_fn = _engines(
            nbytes=nbytes, key=key, K=K, kernel=kernel, m=m, mp=mp,
            block_m=block_m, block_n=block_n, interpret=interpret)
        body = functools.partial(_wave, mp=mp, cap=cap, max_diff=D,
                                 purity_fn=purity_fn, map_fn=map_fn,
                                 apply_fn=apply_fn)
        state = jax.lax.while_loop(
            lambda s: s.changed & ~s.overflow & (s.rounds < max_rounds),
            lambda s: body(s, m), state)
    else:
        if kernel == "ref":
            s1, s2 = _ref_stages_jit(mp, cap, D, K, L, nbytes, key)
        else:
            purity_fn, map_fn, apply_fn = _engines(
                nbytes=nbytes, key=key, K=K, kernel=kernel, m=m, mp=mp,
                block_m=block_m, block_n=block_n, interpret=interpret)
            s1 = functools.partial(_stage1, mp=mp, cap=cap, max_diff=D,
                                   purity_fn=purity_fn)
            s2 = functools.partial(_stage2, mp=mp, max_diff=D,
                                   map_fn=map_fn, apply_fn=apply_fn)
        rounds = 0
        while rounds < max_rounds:
            p_items, p_chk, p_side, keep, n_new, overflow = s1(
                state.sums, state.checks, state.counts, state.rec_checks,
                state.n_rec, m)
            rounds += 1
            if bool(overflow) or int(n_new) == 0:
                state = state._replace(changed=jnp.array(False),
                                       overflow=jnp.asarray(overflow),
                                       rounds=jnp.int32(rounds))
                break
            state = s2(state, p_items, p_chk, p_side, keep, m)
            state = state._replace(changed=jnp.array(True),
                                   rounds=jnp.int32(rounds))

    empty = (state.counts[:, 0] == 0) & (state.checks[:, 0] == 0) & \
            (state.checks[:, 1] == 0) & jnp.all(state.sums == 0, axis=1)
    success = jnp.all(empty) & ~state.overflow
    return state, success


# ---------------------------------------------------------------------------
# Batched wave loop: S independent shard decodes as ONE device program.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _batched_wave_jit(S: int, mp: int, cap: int, max_diff: int, K: int,
                      L: int, nbytes: int, key):
    """One jitted, ``vmap``-ed peel wave over the unit axis.

    Cached per static-shape bucket ``(S, mp, cap, max_diff, K, L)``; the
    per-unit prefix lengths ``m`` enter as a traced ``(S,)`` vector, so a
    set of growing unit prefixes re-uses one compiled program until the
    *longest* unit crosses a tile boundary.  Always the ref engine: dense
    jnp stages vmap cleanly and compile for both CPU and TPU.
    """
    purity_fn, map_fn, apply_fn = _engines(
        nbytes=nbytes, key=key, K=K, kernel="ref", m=None, mp=mp,
        block_m=mp, block_n=cap, interpret=True)
    wave = functools.partial(_wave, mp=mp, cap=cap, max_diff=max_diff,
                             purity_fn=purity_fn, map_fn=map_fn,
                             apply_fn=apply_fn)
    return jax.jit(jax.vmap(wave, in_axes=(0, 0)))


def peel_waves_batched(sums, checks, counts, *, m, nbytes: int, key,
                       max_diff: int, K: int, max_rounds: int = 10_000,
                       block_n: int = 256, use_while_loop: bool = False):
    """Wave-peel ``S`` decode units' difference symbols in lockstep.

    The batched counterpart of :func:`peel_waves` for fan-out serving: the
    inputs carry a leading **unit axis** — sums ``(S, mp, L)`` uint32,
    checks ``(S, mp, 2)`` uint32, counts ``(S, mp, 1)`` int32 — where
    ``mp`` is the *shared* tile bucket (every unit padded to the longest
    unit's bucket; rows ``[m[s], mp)`` of unit ``s`` must be zero).  A unit
    is one independent residual prefix: one shard of a sharded session,
    or, through the protocol engine's cross-peer batching, any ragged
    peer×shard pair that landed in this shape bucket.  ``m`` is a ``(S,)``
    int32 vector of true per-unit prefix lengths and is traced data, not a
    static shape, so ragged unit progress batches into one program.

    Every wave is one vmapped dispatch of the ref-engine stages over the
    unit axis (:func:`_batched_wave_jit`); a unit whose wave recovers
    nothing simply no-ops while hotter units keep peeling, and a unit
    that trips ``max_diff`` freezes its own state and raises only its own
    ``overflow`` flag — the other units are unaffected (per-unit host
    fallback, not all-unit).

    Returns ``(state, success)``: a :class:`PeelState` whose every leaf has
    the leading unit axis, and a ``(S,)`` bool of per-unit success (all
    of the unit's symbols emptied and no overflow).

    ``use_while_loop=True`` stages the whole loop into the jit program via
    ``jax.lax.while_loop`` (one device dispatch total — the TPU serving
    path); the default Python loop issues one batched dispatch per wave,
    which is the right trade on CPU where each jitted wave is cheap but
    staging thousands of waves is not.
    """
    S, mp, L = sums.shape
    D = max_diff
    cap = min(2 * max(D, 1), mp)
    cap = max(((cap + block_n - 1) // block_n) * block_n, block_n)
    key = tuple(key)
    state = PeelState(
        sums=jnp.asarray(sums, jnp.uint32),
        checks=jnp.asarray(checks, jnp.uint32),
        counts=jnp.asarray(counts, jnp.int32),
        rec_items=jnp.zeros((S, D, L), jnp.uint32),
        rec_checks=jnp.zeros((S, D, 2), jnp.uint32),
        rec_sides=jnp.zeros((S, D), jnp.int32),
        n_rec=jnp.zeros(S, jnp.int32),
        changed=jnp.ones(S, bool),
        overflow=jnp.zeros(S, bool),
        rounds=jnp.zeros(S, jnp.int32),
    )
    m = jnp.asarray(m, jnp.int32)
    wave = _batched_wave_jit(S, mp, cap, D, K, L, nbytes, key)
    if use_while_loop:
        state = jax.lax.while_loop(
            lambda s: jnp.any(s.changed & ~s.overflow) &
            jnp.all(s.rounds < max_rounds),
            lambda s: wave(s, m), state)
    else:
        while True:
            state = wave(state, m)
            if not bool(jnp.any(state.changed & ~state.overflow)) or \
                    int(state.rounds.max()) >= max_rounds:
                break
    empty = (state.counts[..., 0] == 0) & (state.checks[..., 0] == 0) & \
            (state.checks[..., 1] == 0) & jnp.all(state.sums == 0, axis=2)
    success = jnp.all(empty, axis=1) & ~state.overflow
    return state, success
