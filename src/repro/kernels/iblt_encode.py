"""Pallas TPU kernel: coded-symbol accumulation (the XOR hot loop).

Part 2 of the encoder (paper §7.2 shows XOR-summing dominates compute).
TPUs have no scatter-XOR, so the Go design (heap + pointer-chased XOR into
one symbol at a time) is replaced by dense VPU work (DESIGN.md §3):

  grid (m_blocks, n_blocks) — n innermost so each (BM, L) output tile stays
  resident in VMEM while every item block streams past it once.  For item
  block j and symbol tile i: build an equality mask between the block's
  mapped indices (BN, K) and the tile's symbol iota (BM,), then XOR-reduce
  masked items over the item axis with a log2(BN) halving tree.

VMEM working set: items (BN·L) + idx (BN·K) + out tile (BM·(L+3)) words
plus the transient masked product (BN·BM·L u32) that feeds the XOR tree —
BN=256, BM=256, L=8 → ~2 MB transient, inside the ~16 MB v5e VMEM with
double buffering.  BM is 128-aligned for lane-width friendliness; block
sizes are tunable (see EXPERIMENTS.md §Perf for the sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tree_xor(v):
    """XOR-reduce axis 0 of (B, ...) — B a power of two — in log2(B) steps."""
    b = v.shape[0]
    while b > 1:
        b //= 2
        v = v[:b] ^ v[b:2 * b]
    return v[0]


def _kernel(items_ref, idx_ref, chk_ref, sums_ref, checks_ref, counts_ref,
            *, K: int, block_m: int, m: int):
    i = pl.program_id(0)   # symbol tile
    j = pl.program_id(1)   # item block (innermost: accumulation)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        checks_ref[...] = jnp.zeros_like(checks_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    items = items_ref[...]          # (BN, L) uint32
    chks = chk_ref[...]             # (BN, 2) uint32
    idxs = idx_ref[...]             # (BN, K) int32
    bn, L = items.shape
    base = i * block_m
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, block_m), 1) + base

    # The chain is strictly increasing, so an item maps to a given symbol at
    # most once within its K slots: the (BN, K, BM) equality tensor reduces
    # to a (BN, BM) mask with `any` — no loop over K, ~25 VPU ops total.
    eq = (idxs[:, :, None] == lane[:, None, :]) & (idxs[:, :, None] < m)
    mask = jnp.any(eq, axis=1)                         # (BN, BM)
    mask_u = mask.astype(jnp.uint32)
    counts_ref[...] = counts_ref[...] + \
        jnp.sum(mask, axis=0, dtype=jnp.int32)[:, None]
    sums_ref[...] = sums_ref[...] ^ \
        _tree_xor(mask_u[:, :, None] * items[:, None, :])
    checks_ref[...] = checks_ref[...] ^ \
        _tree_xor(mask_u[:, :, None] * chks[:, None, :])


def iblt_encode(items, idxs, chks, *, m: int, block_m: int = 256,
                block_n: int = 256, interpret: bool = True):
    """Accumulate coded symbols.

    items (n, L) uint32, idxs (n, K) int32 (pad = m), chks (n, 2) uint32
    -> (sums (m', L) uint32, checks (m', 2) uint32, counts (m', 1) int32)
    with m' = m rounded up to block_m (ops.py trims).
    """
    n, L = items.shape
    K = idxs.shape[1]
    assert n % block_n == 0
    mp = ((m + block_m - 1) // block_m) * block_m
    grid = (mp // block_m, n // block_n)
    kernel = functools.partial(_kernel, K=K, block_m=block_m, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, L), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_n, K), lambda i, j: (j, 0)),
                  pl.BlockSpec((block_n, 2), lambda i, j: (j, 0))],
        out_specs=[pl.BlockSpec((block_m, L), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_m, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((mp, L), jnp.uint32),
                   jax.ShapeDtypeStruct((mp, 2), jnp.uint32),
                   jax.ShapeDtypeStruct((mp, 1), jnp.int32)],
        interpret=interpret,
    )(items, idxs, chks)
