"""Optimizers: AdamW and Adafactor (factored second moment), with
configurable state dtype — the 1T-param configs use factored v + bf16 m to
fit 512 × 16 GB (see DESIGN.md §5).  States inherit parameter shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"
    momentum: bool = True        # adafactor: disable to halve state bytes
    accum_dtype: str = "float32"
    warmup: int = 100
    total_steps: int = 10_000


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _factored_dims(shape):
    """Last two dims if both > 1 (Adafactor row/col factoring)."""
    if len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1:
        return len(shape) - 2, len(shape) - 1
    return None


def init_state(cfg: OptConfig, params):
    dt = jnp.dtype(cfg.state_dtype)

    def per_leaf(p):
        if cfg.kind == "adamw":
            return {"m": jnp.zeros_like(p, dtype=dt),
                    "v": jnp.zeros_like(p, dtype=dt)}
        fd = _factored_dims(p.shape)
        if fd is None:
            st = {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        else:
            r, c = fd
            st = {"vr": jnp.zeros(p.shape[:c] + p.shape[c + 1:], jnp.float32),
                  "vc": jnp.zeros(p.shape[:r] + p.shape[r + 1:], jnp.float32)}
        if cfg.momentum:
            st["m"] = jnp.zeros_like(p, dtype=dt)
        return st

    return {"step": jnp.zeros((), jnp.int32),
            "opt": jax.tree.map(per_leaf, params)}


def state_specs(cfg: OptConfig, param_specs):
    """PartitionSpecs for the optimizer state, mirroring the params."""
    from jax.sharding import PartitionSpec as P

    def per_leaf(sp):
        sp = sp if isinstance(sp, P) else P()
        if cfg.kind == "adamw":
            return {"m": sp, "v": sp}
        # factored dims drop the last / second-to-last axes
        t = tuple(sp)
        if len(t) >= 2:
            st = {"vr": P(*(t[:-2] + (t[-2],))), "vc": P(*(t[:-2] + (t[-1],)))}
        else:
            st = {"v": sp}
        if cfg.momentum:
            st["m"] = sp
        return st

    return {"step": P(),
            "opt": jax.tree.map(per_leaf, param_specs,
                                is_leaf=lambda x: isinstance(x, P))}


# Leaves above this many elements get their fp32 optimizer math chunked
# over the leading (stacked-layers) axis with lax.map: the transient fp32
# copies of a 61-layer MoE weight stack would otherwise cost ~5 GB each.
_CHUNK_ELEMS = 1 << 26


def _global_norm(grads):
    def leaf_sq(g):
        if g.size > _CHUNK_ELEMS and g.ndim >= 2:
            return jnp.sum(jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), g))
        return jnp.sum(jnp.square(g.astype(jnp.float32)))

    return jnp.sqrt(sum(leaf_sq(g) for g in jax.tree.leaves(grads)))


def apply_updates(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = _global_norm(grads)
    gscale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path_p, p, g, st):
        g = g.astype(jnp.float32) * gscale
        if "m" in st:
            m = st["m"].astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        else:
            m, bc1_ = g, 1.0  # momentum-free adafactor
        if "v" in st:
            v = st["v"].astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
            upd_ = (m / (bc1 if "m" in st else 1.0)) / \
                (jnp.sqrt(v / bc2) + cfg.eps)
            new_st = dict(st, v=v.astype(st["v"].dtype))
            if "m" in st:
                new_st["m"] = m.astype(st["m"].dtype)
        else:
            # p shape (..., R, C): vr = mean over C -> (..., R); vc = mean
            # over R -> (..., C); V ≈ vr ⊗ vc / mean(vr).
            g2 = jnp.square(g) + 1e-30
            vr = st["vr"] * cfg.b2 + jnp.mean(g2, axis=-1) * (1 - cfg.b2)
            vc = st["vc"] * cfg.b2 + jnp.mean(g2, axis=-2) * (1 - cfg.b2)
            vrb, vcb = vr / bc2, vc / bc2
            denom = (vrb[..., :, None] * vcb[..., None, :] /
                     jnp.maximum(jnp.mean(vrb, axis=-1)[..., None, None],
                                 1e-30))
            upd_ = (m / (bc1 if "m" in st else 1.0)) * \
                jax.lax.rsqrt(denom + 1e-30)
            new_st = dict(st, vr=vr, vc=vc)
            if "m" in st:
                new_st["m"] = m.astype(st["m"].dtype)
        upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        return new_p, new_st

    def upd_leaf(p, g, st):
        if p.size > _CHUNK_ELEMS and p.ndim >= 3:
            # chunk the fp32 math over the stacked-layers axis; factored
            # vr/vc drop trailing dims, so the leading axis lines up.
            return jax.lax.map(lambda a: upd(None, *a), (p, g, st))
        return upd(None, p, g, st)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tree.flatten_up_to(state["opt"])
    out = [upd_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tree.unflatten([o[0] for o in out])
    new_opt = tree.unflatten([o[1] for o in out])
    return new_params, {"step": step, "opt": new_opt}, \
        {"lr": lr, "grad_norm": gnorm}
