"""Train step: loss + grad + optimizer update, with optional gradient
accumulation and int8 error-feedback gradient compression for the cross-pod
all-reduce (train/compression.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .optim import OptConfig, apply_updates, init_state


def make_opt_config(cfg, total_steps: int = 10_000) -> OptConfig:
    return OptConfig(kind=cfg.optimizer, state_dtype=cfg.opt_state_dtype,
                     momentum=getattr(cfg, "adafactor_momentum", True),
                     accum_dtype=getattr(cfg, "grad_accum_dtype", "float32"),
                     total_steps=total_steps)


def make_train_step(model, opt_cfg: OptConfig, microbatches: int = 1,
                    compression=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With microbatches > 1 the global batch is split on the batch
    axis and gradients are accumulated in fp32 (sequential lax.scan — the
    pipeline-parallel path interleaves instead; see train/pipeline.py)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        adt = jnp.dtype(opt_cfg.accum_dtype)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)

        def body(acc, mb):
            loss, metrics, grads = single(params, mb)
            acc = jax.tree.map(lambda a, g: a + (g.astype(adt) /
                               microbatches).astype(adt), acc, grads)
            return acc, (loss, metrics)

        grads, (losses, metricses) = jax.lax.scan(body, zero, micro)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metricses)
        return jnp.mean(losses), metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if compression is not None:
            grads, opt_state = compression.apply(grads, opt_state)
        params, opt_state, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def init_train_state(model, opt_cfg: OptConfig, key):
    params, specs = model.init(key)
    opt_state = init_state(opt_cfg, params)
    return params, opt_state, specs
