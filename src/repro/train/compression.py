"""Int8 error-feedback gradient compression for the cross-pod (DCN)
all-reduce (DESIGN.md §5, distributed-optimization tricks).

Gradients are quantized to int8 with a per-leaf scale before the (slow,
cross-pod) reduction; the quantization residual is carried in an
error-feedback buffer and added back next step, so the *accumulated*
gradient is unbiased and SGD-style convergence is preserved (Seide et al.;
Karimireddy et al. 2019).  8× fewer bytes on the pod-crossing collective.

Plugs into make_train_step(compression=ErrorFeedbackInt8(...)); the
error buffer lives inside opt_state under "ef".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


class ErrorFeedbackInt8:
    """apply(grads, opt_state) -> (compressed-roundtrip grads, opt_state).

    In a real multi-pod run the int8 payload is what crosses the DCN
    (the psum happens on the dequantized values per GSPMD's reduction);
    numerically this class is exactly the quantize->transport->dequantize
    round trip plus error feedback, so its convergence behavior is what
    tests validate.
    """

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, grads, opt_state):
        ef = opt_state.get("ef")
        if ef is None:
            ef = self.init(grads)

        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = _quantize(corrected)
            deq = _dequantize(q, scale)
            return deq.astype(g.dtype), corrected - deq

        flat_g, tree = jax.tree.flatten(grads)
        flat_e = tree.flatten_up_to(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = tree.unflatten([o[0] for o in outs])
        new_e = tree.unflatten([o[1] for o in outs])
        opt_state = dict(opt_state)
        opt_state["ef"] = new_e
        return new_g, opt_state

    @staticmethod
    def wire_bytes(grads) -> tuple[int, int]:
        """(compressed, raw) bytes for the cross-pod reduction."""
        raw = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
        comp = sum(g.size + 4 for g in jax.tree.leaves(grads))
        return comp, raw
