"""Kimi K2 — trillion-param MoE (arXiv:2501.kimi2) [paper-table]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163_840, n_experts=384, experts_per_token=8,
    qk_norm=False, moe_mode="ep",
    # 1T params: factored second moment + bf16 states to fit 512×16 GB
    optimizer="adafactor", opt_state_dtype="bfloat16",
    adafactor_momentum=False,     # 1T params: m alone is 2 TB
    grad_accum_dtype="bfloat16",  # fp32 accum would be 16 GB/device
    microbatches=8,               # keeps MoE dispatch buffers ~1 GB
    skip_shapes=("long_500k",),  # full attention (DESIGN.md §Arch-applicability)
)
