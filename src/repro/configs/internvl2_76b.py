"""InternVL2-76B — InternViT frontend (stub patch embeddings) + InternLM2
backbone (arXiv:2404.16821)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28_672,
    vocab=128_256, frontend="vision_stub", n_patches=256, microbatches=2,
    optimizer="adafactor", opt_state_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
