"""StarCoder2-3B — GQA, RoPE (arXiv:2402.19173) [hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12_288,
    vocab=49_152,
    skip_shapes=("long_500k",),
)
