"""Yi-9B — llama-arch GQA (arXiv:2403.04652) [hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11_008,
    vocab=64_000,
    skip_shapes=("long_500k",),
)
