"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12_288, n_heads=96, n_kv_heads=8, d_ff=28_672,
    vocab=32_768,
    optimizer="adafactor", opt_state_dtype="bfloat16", microbatches=2,
    skip_shapes=("long_500k",),
)
