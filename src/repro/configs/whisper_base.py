"""Whisper-base enc-dec; conv/audio frontend is a stub (precomputed frame
embeddings) per the assignment [arXiv:2212.04356]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51_865, frontend="audio_stub", encoder_frames=1500,
    tie_embeddings=True,
    skip_shapes=("long_500k",),  # full-attention decoder
)
