"""Config registry: ``get_config(arch)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from .base import FULL_ATTENTION_SKIPS, SHAPES, ModelConfig, ShapeConfig

_MODULES = [
    "kimi_k2_1t_a32b", "qwen3_moe_30b_a3b", "rwkv6_1_6b", "whisper_base",
    "recurrentgemma_2b", "internvl2_76b", "qwen3_4b", "starcoder2_3b",
    "mistral_large_123b", "yi_9b",
]

REGISTRY: dict[str, ModelConfig] = {}
for _m in _MODULES:
    cfg = __import__(f"repro.configs.{_m}", fromlist=["CONFIG"]).CONFIG
    REGISTRY[cfg.name] = cfg

ARCHS = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honoring per-arch skips."""
    for a in ARCHS:
        cfg = REGISTRY[a]
        for s in SHAPES.values():
            skipped = s.name in cfg.skip_shapes
            if skipped and not include_skipped:
                continue
            yield a, s.name, skipped


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny dims, few layers/experts, CPU-safe
    fp32.  The full configs are touched only by the dry-run (abstract)."""
    cfg = get_config(name)
    per = len(cfg.block_pattern)
    small = dict(
        n_layers=max(2 * per, 2 if per == 1 else per) + (1 if per > 1 else 0),
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=16, d_ff=128, vocab=256, dtype="float32",
        fsdp=False, remat=False, opt_state_dtype="float32",
        optimizer="adamw",
    )
    if cfg.n_experts:
        small.update(n_experts=8, experts_per_token=2)
    if cfg.family == "encdec":
        small.update(encoder_layers=2, encoder_frames=16)
    if cfg.frontend == "vision_stub":
        small.update(n_patches=4)
    if cfg.window:
        small.update(window=8)
    if cfg.d_rnn:
        small.update(d_rnn=64)
    return dataclasses.replace(cfg, **small)
