"""Qwen3-4B — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab=151_936, qk_norm=True,
    skip_shapes=("long_500k",),
)
