"""Qwen3-30B-A3B MoE [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151_936, n_experts=128, experts_per_token=8,
    qk_norm=True, moe_mode="ep", microbatches=4,
    skip_shapes=("long_500k",),
)
