"""RecurrentGemma-2B — RG-LRU + local attention, 1:2 ratio
(arXiv:2402.19427) [hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, window=2048, d_rnn=2560,
    block_pattern=("rglru", "rglru", "local"),
    # bounded state (RG-LRU + 2k window): long_500k runs
)
