"""Config system: architectures (assigned pool) × input shapes."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0            # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_mode: str = "ep"       # ep (all_to_all expert parallel) | tp (sliced experts)
    capacity_factor: float = 1.25
    # attention flavor
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int = 0            # sliding-window size for "local" blocks
    block_pattern: tuple = ("attn",)   # repeated over depth
    # recurrent dims
    d_rnn: int = 0             # RG-LRU width (0 -> d_model)
    # enc-dec / multimodal
    encoder_layers: int = 0
    encoder_frames: int = 0    # fixed encoder length (whisper: 1500)
    frontend: str = "none"     # none | audio_stub | vision_stub
    n_patches: int = 0         # vision_stub prompt patches
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # numerics / distribution
    dtype: str = "bfloat16"
    fsdp: bool = True
    remat: bool = True
    optimizer: str = "adamw"
    opt_state_dtype: str = "float32"
    adafactor_momentum: bool = True
    grad_accum_dtype: str = "float32"
    microbatches: int = 1   # train grad-accumulation splits
    # which shapes are lowerable for this arch ("" = all); see DESIGN.md
    skip_shapes: tuple = ()

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab padded to a multiple of 128 so the
        vocab dim shards over any mesh axis (MaxText-style); padded logits
        are masked in Model.logits."""
        return ((self.vocab + 127) // 128) * 128

    def pattern(self) -> list[str]:
        """Per-layer block kinds, pattern repeated/truncated to n_layers."""
        p = list(self.block_pattern)
        return [p[i % len(p)] for i in range(self.n_layers)]

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Architectures whose every block attends globally (quadratic, unbounded KV)
# cannot run the 512k-decode cell; DESIGN.md §Arch-applicability records the
# skip.  SSM/hybrid archs run it with O(1)/windowed state.
FULL_ATTENTION_SKIPS = ("long_500k",)
