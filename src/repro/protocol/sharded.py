"""Sharded SymbolStream serving — fan-out reconciliation over S shards.

The paper's headline deployment (§7, Ethereum full-state sync) serves
reconciliation to *many* peers over *huge* sets.  One universal stream
already amortizes encoding across peers; sharding bounds the *decode* work
per partition, the same lever PBS uses to keep per-group decode cheap and
the composition trick of multi-party reconciliation over partitioned key
spaces (per-partition sketches are independent, so they merge trivially):

* the key space is hash-partitioned into ``S`` shards by a **stable SipHash
  shard-of-key** (:func:`shard_of`) — derived from the session key via the
  mapping-seed hash that :func:`repro.kernels.common.checksum_and_seed` /
  :func:`repro.core.mapping.map_seeds` already compute, so both ends of a
  session agree on the partition by construction;
* a :class:`ShardedStream` keeps one universal symbol cache *per shard*
  (S independent :class:`~repro.protocol.stream.SymbolStream`\\ s) and
  serves **merged windows**: one wire payload interleaving per-shard
  columnar frames behind a shard-id'd header extension
  (:func:`repro.core.wire.encode_shard_frames`);
* a :class:`ShardedSession` is the S-unit wrapper over the
  :mod:`engine <repro.protocol.engine>`'s
  :class:`~repro.protocol.engine.PeerState`: one incremental decoder per
  shard, every grow step decoded in **one batched device call**
  (:func:`repro.kernels.ops.decode_device_batched` — the peel wave
  ``vmap``-ed over the unit axis, per-unit prefix lengths as data);
* pacing is **per shard**: each shard pulls by its own progress, so a hot
  shard (large local difference) keeps growing its window while settled
  shards — each terminated by its own ρ(0)=1 signal — stop requesting.

Because each shard sees ~d/S of the difference, per-shard ``max_diff``
stays small and the fixed-shape device decoder stays in its fast path; a
shard that still overflows falls back to the exact host peel *alone* and
stays pinned to the host from then on.

Shard invariance: for any S, the union of per-shard symmetric differences
is exactly the unsharded symmetric difference (items never cross shards —
the partition function depends only on the item and the key).
"""
from __future__ import annotations

import numpy as np

from repro.core.hashing import DEFAULT_KEY, bytes_to_words
from repro.core.mapping import map_seeds
from repro.core.wire import encode_shard_frames

from .engine import (PeerState, ProtocolError, execute_round, ingest_payload,
                     offer_round)
from .pacing import Exponential, Pacing
from .reports import (ShardReport, ShardedReport, build_sharded_report)
from .stream import SymbolStream

__all__ = ["ShardReport", "ShardedReport", "ShardedSession", "ShardedStream",
           "run_sharded_session", "shard_of"]


def _coerce_words(items, nbytes: int) -> np.ndarray:
    """Items as (n, L) uint32 little-endian words (accepts bytes rows)."""
    if isinstance(items, np.ndarray) and items.dtype == np.uint32:
        return items
    return bytes_to_words(items, nbytes)


def shard_of(items, n_shards: int, key=DEFAULT_KEY,
             nbytes: int | None = None) -> np.ndarray:
    """Stable shard assignment of each item under a session key.

    Parameters
    ----------
    items: ``(n, L)`` uint32 word rows, ``(n, nbytes)`` uint8 rows, or a
        list of ``bytes`` — same coercions as the encoders.
    n_shards: the partition size S ≥ 1.
    key, nbytes: session geometry; ``nbytes`` defaults to ``4·L`` for word
        input and is required for byte input.

    Returns an ``(n,)`` int64 array of shard ids in ``[0, S)``.

    The id is the high half of the item's mapping-PRNG seed — the SipHash
    of the item under the tweaked session key that the encoder computes
    anyway (:func:`repro.core.mapping.map_seeds`, device twin
    ``kernels.common.checksum_and_seed``) — reduced mod S.  The *high*
    word is used because the seed's low bit is forced odd for the
    xorshift64 state, which would empty every even shard.  Invariants:
    deterministic in (item, key, S); independent of insertion order and of
    which peer evaluates it — both ends of a session compute the identical
    partition.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    words = _coerce_words(items, nbytes)
    if nbytes is None:
        nbytes = 4 * words.shape[1]
    seeds = map_seeds(words, key, nbytes)
    return ((seeds >> np.uint64(32)) % np.uint64(n_shards)).astype(np.int64)


class ShardedStream:
    """S universal symbol caches over a hash-partitioned key space.

    One :class:`~repro.protocol.stream.SymbolStream` per shard; windows of
    several shards merge into a single wire payload (:meth:`payload`).
    Like the unsharded stream, serving never re-encodes: each shard's
    prefix cache extends at most once per request and is shared by every
    peer syncing against this stream.

    Construct with :meth:`from_items`; mutate with :meth:`add_items` /
    :meth:`remove_items`, which route every item to its stable shard.
    """

    def __init__(self, shards: list[SymbolStream], key=DEFAULT_KEY):
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = shards
        self.key = key

    @classmethod
    def from_items(cls, items, nbytes: int, n_shards: int = 8,
                   key=DEFAULT_KEY) -> "ShardedStream":
        """Partition ``items`` into ``n_shards`` streams of ``nbytes``-byte
        items under ``key`` (see :func:`shard_of` for accepted layouts)."""
        words = _coerce_words(items, nbytes) if len(items) else \
            np.zeros((0, (nbytes + 3) // 4), np.uint32)
        ids = shard_of(words, n_shards, key, nbytes)
        shards = [SymbolStream.from_items(words[ids == s], nbytes, key)
                  for s in range(n_shards)]
        return cls(shards, key)

    # -- geometry -----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def nbytes(self) -> int:
        return self.shards[0].nbytes

    @property
    def n_items(self) -> int:
        """Total set size across shards."""
        return sum(s.n_items for s in self.shards)

    @property
    def m(self) -> int:
        """Total symbols materialized across all shard caches."""
        return sum(s.m for s in self.shards)

    # -- set mutation (routed to the owning shard) --------------------------
    def _route(self, items) -> list[np.ndarray]:
        words = _coerce_words(items, self.nbytes)
        ids = shard_of(words, self.n_shards, self.key, self.nbytes)
        return [words[ids == s] for s in range(self.n_shards)]

    def add_items(self, items) -> None:
        for shard, part in zip(self.shards, self._route(items)):
            if len(part):
                shard.add_items(part)

    def remove_items(self, items) -> None:
        for shard, part in zip(self.shards, self._route(items)):
            if len(part):
                shard.remove_items(part)

    # -- serving ------------------------------------------------------------
    def window(self, shard: int, lo: int, hi: int):
        """Zero-copy view of shard ``shard``'s stream symbols [lo, hi)."""
        return self.shards[shard].window(lo, hi)

    def payload(self, requests) -> bytes:
        """One merged wire payload answering per-shard window requests.

        ``requests`` is an iterable of ``(shard, lo, hi)``; the result
        interleaves one self-describing columnar frame per request behind
        shard-id'd extension headers — settled shards simply don't appear.
        """
        frames = [(s, self.shards[s].frames(lo, hi)) for s, lo, hi in requests]
        return encode_shard_frames(frames, self.n_shards)

    # -- convenience --------------------------------------------------------
    def session(self, local: "ShardedStream | None" = None,
                **kwargs) -> "ShardedSession":
        """A :class:`ShardedSession` against this stream's geometry
        (n_shards/nbytes/key inherited when ``local`` is None)."""
        if local is None:
            kwargs.setdefault("n_shards", self.n_shards)
            kwargs.setdefault("nbytes", self.nbytes)
            kwargs.setdefault("key", self.key)
        return ShardedSession(local=local, **kwargs)


class ShardedSession:
    """Incremental reconciliation of a sharded local set against a
    :class:`ShardedStream`, one decoder per shard, one batched device
    decode per grow step.

    A thin S-unit wrapper over the engine's
    :class:`~repro.protocol.engine.PeerState` — validation, absorb,
    shape-bucketed batched dispatch, per-unit overflow fallback and
    termination all live in :mod:`repro.protocol.engine`.

    Parameters
    ----------
    local: the local side as a :class:`ShardedStream` (each shard's encoder
        is subtracted from the matching remote shard), or None to decode S
        raw shard streams (recovers the remote sets themselves).
    n_shards, nbytes, key: partition geometry — inferred from ``local``
        when given.  Both ends must agree on all three (the wire payload
        carries ``n_shards`` and each frame carries ``nbytes``; mismatches
        raise :class:`~repro.protocol.engine.ProtocolError`).
    pacing: per-shard window schedule.  Policies are stateless (a pure
        function of that shard's progress), so one instance drives all
        shards independently; default is the session-standard doubling
        schedule.
    max_m: abort bound on any single shard's stream consumption.
    backend: "host" | "device" | "auto".  "device" decodes all shards that
        received symbols in ONE :func:`repro.kernels.ops.decode_device_batched`
        call per grow step; a shard whose ``max_diff`` overflows falls back
        to the exact host peel for that shard only, and stays **pinned to
        the host** afterwards — a later ``set_backend("device")`` will not
        re-dispatch a residual already known to exceed the device buffers.
    max_diff: per-shard bound on the device decoder's fixed recovered-item
        buffers (sharding divides the difference ~uniformly, so this can be
        ~d/S plus slack rather than d).

    Invariants: windows must arrive in order per shard (overlap with
    already-consumed symbols is trimmed, gaps raise); each shard terminates
    on its own ρ(0)=1 signal; ``decoded`` is the conjunction over shards.
    """

    def __init__(self, local: ShardedStream | None = None,
                 n_shards: int | None = None, nbytes: int | None = None,
                 pacing: Pacing | None = None, key=None,
                 max_m: int = 1 << 22, backend: str = "host",
                 max_diff: int | None = None):
        if local is not None:
            n_shards = local.n_shards if n_shards is None else n_shards
            nbytes = local.nbytes if nbytes is None else nbytes
            key = local.key if key is None else key
            if n_shards != local.n_shards:
                raise ValueError(f"n_shards={n_shards} but local partition "
                                 f"has {local.n_shards}")
        if n_shards is None or nbytes is None:
            raise ValueError("need n_shards and nbytes (or a local "
                             "ShardedStream to infer them from)")
        key = DEFAULT_KEY if key is None else key
        self.n_shards = n_shards
        self.nbytes = nbytes
        self.key = key
        # per-shard decoders peel on the host; the ENGINE owns the device
        # path so all units (here: shards) batch into one dispatch
        self._peer = PeerState(
            nbytes=nbytes, key=key,
            locals_=[local.shards[s].encoder if local else None
                     for s in range(n_shards)],
            pacing=pacing or Exponential(block=8, growth=2.0),
            max_m=max_m, backend=backend, max_diff=max_diff, sharded=True)
        self._shards = self._peer.units

    # -- state --------------------------------------------------------------
    @property
    def backend(self) -> str:
        return self._peer.backend

    def set_backend(self, backend: str) -> None:
        """Switch the decode engine; safe between grow steps (both engines
        maintain identical per-shard decoder state).  Shards that already
        overflowed the device buffers stay pinned to the host."""
        self._peer.set_backend(backend)

    @property
    def pacing(self) -> Pacing:
        return self._peer.pacing

    @pacing.setter
    def pacing(self, pacing: Pacing) -> None:
        self._peer.pacing = pacing

    @property
    def max_m(self) -> int:
        return self._peer.max_m

    @property
    def max_diff(self) -> int | None:
        return self._peer.max_diff

    @property
    def bytes_received(self) -> int:
        return self._peer.bytes_received

    @property
    def grow_steps(self) -> int:
        return self._peer.grow_steps

    @property
    def decoded(self) -> bool:
        """True once every shard has hit its ρ(0)=1 termination signal."""
        return self._peer.decoded

    @property
    def symbols_received(self) -> int:
        return self._peer.symbols_received

    # -- pull protocol ------------------------------------------------------
    def requests(self) -> list[tuple[int, int, int]]:
        """Next window [lo, hi) per still-undecoded shard; [] when done.

        Each shard's window size comes from the shared pacing policy
        applied to *that shard's* progress — settled shards drop out of the
        list, hot shards keep growing.  Raises ``RuntimeError`` if any
        shard exceeds ``max_m`` without decoding.
        """
        return self._peer.requests()

    def offer_payload(self, data: bytes) -> bool:
        """Consume one merged wire payload (all shards' frames), then run
        ONE batched decode over every shard that received symbols.
        Returns ``decoded``."""
        execute_round(ingest_payload(self._peer, data))
        return self.decoded

    def offer_windows(self, windows) -> bool:
        """Feed ``(shard, symbols, start)`` windows (the in-process peer of
        :meth:`offer_payload`), absorbing every window first and then
        decoding all touched shards in one batched step.  Validation is
        all-or-nothing: every window is checked (shard id, order,
        geometry) before ANY state mutates, so a rejected round can be
        corrected and retried without losing symbols.  Returns
        ``decoded``."""
        return offer_round(self._peer, windows)

    # -- outcome ------------------------------------------------------------
    def result(self):
        """(only_remote, only_local) uint32 word arrays, shards merged."""
        rem = [u.decoder.result()[0] for u in self._shards]
        loc = [u.decoder.result()[1] for u in self._shards]
        return np.concatenate(rem), np.concatenate(loc)

    def report(self) -> ShardedReport:
        return build_sharded_report(self._peer)


def run_sharded_session(stream: ShardedStream, session: ShardedSession,
                        wire: bool = True,
                        backend: str | None = None) -> ShardedReport:
    """Drive ``session`` to completion against a :class:`ShardedStream`.

    Each round trip gathers every undecoded shard's window request, answers
    all of them with one merged payload (``wire=True``, the native sharded
    mode — exactly the bytes two networked peers exchange) or with
    in-process zero-copy windows (``wire=False``), and hands them to the
    session, which decodes all touched shards in one batched step — a
    single-peer, non-pipelined
    :class:`~repro.protocol.engine.ReconcileEngine` loop.
    ``backend`` switches the session's engine first, like
    :meth:`ShardedSession.set_backend`, and persists afterwards.

    Both ends must run the identical partition: mixed shard counts would
    silently mis-reconcile in-process (the wire path carries S in the
    payload header), so the driver rejects them up front.
    """
    from .engine import serve
    if stream.n_shards != session.n_shards:
        raise ProtocolError(f"partition mismatch: stream has "
                            f"{stream.n_shards} shards, session "
                            f"{session.n_shards}")
    return serve([(stream, session)], wire=wire, backend=backend,
                 pipeline=False)[0]
