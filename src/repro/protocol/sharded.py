"""Sharded SymbolStream serving — fan-out reconciliation over S shards.

The paper's headline deployment (§7, Ethereum full-state sync) serves
reconciliation to *many* peers over *huge* sets.  One universal stream
already amortizes encoding across peers; sharding bounds the *decode* work
per partition, the same lever PBS uses to keep per-group decode cheap and
the composition trick of multi-party reconciliation over partitioned key
spaces (per-partition sketches are independent, so they merge trivially):

* the key space is hash-partitioned into ``S`` shards by a **stable SipHash
  shard-of-key** (:func:`shard_of`) — derived from the session key via the
  mapping-seed hash that :func:`repro.kernels.common.checksum_and_seed` /
  :func:`repro.core.mapping.map_seeds` already compute, so both ends of a
  session agree on the partition by construction;
* a :class:`ShardedStream` keeps one universal symbol cache *per shard*
  (S independent :class:`~repro.protocol.stream.SymbolStream`\\ s) and
  serves **merged windows**: one wire payload interleaving per-shard
  columnar frames behind a shard-id'd header extension
  (:func:`repro.core.wire.encode_shard_frames`);
* a :class:`ShardedSession` holds one incremental decoder per shard and
  decodes every shard's residual in **one batched device call** per grow
  step (:func:`repro.kernels.ops.decode_device_batched` — the peel wave
  ``vmap``-ed over the shard axis, per-shard prefix lengths as data);
* pacing is **per shard**: each shard pulls by its own progress, so a hot
  shard (large local difference) keeps growing its window while settled
  shards — each terminated by its own ρ(0)=1 signal — stop requesting.

Because each shard sees ~d/S of the difference, per-shard ``max_diff``
stays small and the fixed-shape device decoder stays in its fast path; a
shard that still overflows falls back to the exact host peel *alone*.

Shard invariance: for any S, the union of per-shard symmetric differences
is exactly the unsharded symmetric difference (items never cross shards —
the partition function depends only on the item and the key).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.decoder import resolve_backend
from repro.core.hashing import DEFAULT_KEY, bytes_to_words, words_to_bytes
from repro.core.mapping import map_seeds
from repro.core.stream import StreamDecoder
from repro.core.wire import decode_shard_frames, encode_shard_frames

from .pacing import Exponential, Pacing
from .session import ProtocolError
from .stream import SymbolStream


def _coerce_words(items, nbytes: int) -> np.ndarray:
    """Items as (n, L) uint32 little-endian words (accepts bytes rows)."""
    if isinstance(items, np.ndarray) and items.dtype == np.uint32:
        return items
    return bytes_to_words(items, nbytes)


def shard_of(items, n_shards: int, key=DEFAULT_KEY,
             nbytes: int | None = None) -> np.ndarray:
    """Stable shard assignment of each item under a session key.

    Parameters
    ----------
    items: ``(n, L)`` uint32 word rows, ``(n, nbytes)`` uint8 rows, or a
        list of ``bytes`` — same coercions as the encoders.
    n_shards: the partition size S ≥ 1.
    key, nbytes: session geometry; ``nbytes`` defaults to ``4·L`` for word
        input and is required for byte input.

    Returns an ``(n,)`` int64 array of shard ids in ``[0, S)``.

    The id is the high half of the item's mapping-PRNG seed — the SipHash
    of the item under the tweaked session key that the encoder computes
    anyway (:func:`repro.core.mapping.map_seeds`, device twin
    ``kernels.common.checksum_and_seed``) — reduced mod S.  The *high*
    word is used because the seed's low bit is forced odd for the
    xorshift64 state, which would empty every even shard.  Invariants:
    deterministic in (item, key, S); independent of insertion order and of
    which peer evaluates it — both ends of a session compute the identical
    partition.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    words = _coerce_words(items, nbytes)
    if nbytes is None:
        nbytes = 4 * words.shape[1]
    seeds = map_seeds(words, key, nbytes)
    return ((seeds >> np.uint64(32)) % np.uint64(n_shards)).astype(np.int64)


class ShardedStream:
    """S universal symbol caches over a hash-partitioned key space.

    One :class:`~repro.protocol.stream.SymbolStream` per shard; windows of
    several shards merge into a single wire payload (:meth:`payload`).
    Like the unsharded stream, serving never re-encodes: each shard's
    prefix cache extends at most once per request and is shared by every
    peer syncing against this stream.

    Construct with :meth:`from_items`; mutate with :meth:`add_items` /
    :meth:`remove_items`, which route every item to its stable shard.
    """

    def __init__(self, shards: list[SymbolStream], key=DEFAULT_KEY):
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = shards
        self.key = key

    @classmethod
    def from_items(cls, items, nbytes: int, n_shards: int = 8,
                   key=DEFAULT_KEY) -> "ShardedStream":
        """Partition ``items`` into ``n_shards`` streams of ``nbytes``-byte
        items under ``key`` (see :func:`shard_of` for accepted layouts)."""
        words = _coerce_words(items, nbytes) if len(items) else \
            np.zeros((0, (nbytes + 3) // 4), np.uint32)
        ids = shard_of(words, n_shards, key, nbytes)
        shards = [SymbolStream.from_items(words[ids == s], nbytes, key)
                  for s in range(n_shards)]
        return cls(shards, key)

    # -- geometry -----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def nbytes(self) -> int:
        return self.shards[0].nbytes

    @property
    def n_items(self) -> int:
        """Total set size across shards."""
        return sum(s.n_items for s in self.shards)

    @property
    def m(self) -> int:
        """Total symbols materialized across all shard caches."""
        return sum(s.m for s in self.shards)

    # -- set mutation (routed to the owning shard) --------------------------
    def _route(self, items) -> list[np.ndarray]:
        words = _coerce_words(items, self.nbytes)
        ids = shard_of(words, self.n_shards, self.key, self.nbytes)
        return [words[ids == s] for s in range(self.n_shards)]

    def add_items(self, items) -> None:
        for shard, part in zip(self.shards, self._route(items)):
            if len(part):
                shard.add_items(part)

    def remove_items(self, items) -> None:
        for shard, part in zip(self.shards, self._route(items)):
            if len(part):
                shard.remove_items(part)

    # -- serving ------------------------------------------------------------
    def window(self, shard: int, lo: int, hi: int):
        """Zero-copy view of shard ``shard``'s stream symbols [lo, hi)."""
        return self.shards[shard].window(lo, hi)

    def payload(self, requests) -> bytes:
        """One merged wire payload answering per-shard window requests.

        ``requests`` is an iterable of ``(shard, lo, hi)``; the result
        interleaves one self-describing columnar frame per request behind
        shard-id'd extension headers — settled shards simply don't appear.
        """
        frames = [(s, self.shards[s].frames(lo, hi)) for s, lo, hi in requests]
        return encode_shard_frames(frames, self.n_shards)

    # -- convenience --------------------------------------------------------
    def session(self, local: "ShardedStream | None" = None,
                **kwargs) -> "ShardedSession":
        """A :class:`ShardedSession` against this stream's geometry
        (n_shards/nbytes/key inherited when ``local`` is None)."""
        if local is None:
            kwargs.setdefault("n_shards", self.n_shards)
            kwargs.setdefault("nbytes", self.nbytes)
            kwargs.setdefault("key", self.key)
        return ShardedSession(local=local, **kwargs)


@dataclasses.dataclass
class ShardReport:
    """Per-shard slice of a completed sharded reconciliation."""
    shard: int
    only_remote: np.ndarray   # (r, L) uint32 words — remote-only, this shard
    only_local: np.ndarray    # (s, L) uint32 words — local-only, this shard
    symbols_used: int         # shard prefix length at its decode signal
    symbols_received: int     # including pacing overshoot
    remote_items: int | None  # |remote shard set|, from frame headers


@dataclasses.dataclass
class ShardedReport:
    """Outcome of a completed :class:`ShardedSession`.

    The aggregate fields mirror :class:`~repro.protocol.session.SessionReport`
    (the union over shards *is* the unsharded difference — shard
    invariance); ``shards`` keeps the per-shard breakdown.
    """
    shards: list[ShardReport]
    only_remote: np.ndarray   # (r, L) uint32 words, all shards concatenated
    only_local: np.ndarray    # (s, L) uint32 words
    nbytes: int               # item length ℓ
    symbols_used: int         # Σ per-shard symbols at decode
    symbols_received: int     # Σ per-shard symbols received
    bytes_received: int       # total merged-payload traffic (0 in-process)
    remote_items: int | None  # Σ per-shard set sizes (None until all known)
    grow_steps: int           # merged windows consumed (batched decodes run)

    def only_remote_bytes(self) -> np.ndarray:
        """(r, ℓ) uint8 — remote-exclusive items as raw bytes."""
        return words_to_bytes(self.only_remote, self.nbytes)

    def only_local_bytes(self) -> np.ndarray:
        return words_to_bytes(self.only_local, self.nbytes)

    def overhead(self, d: int | None = None) -> float:
        """symbols_used / d (defaults to the recovered difference size)."""
        if d is None:
            d = self.only_remote.shape[0] + self.only_local.shape[0]
        return self.symbols_used / max(d, 1)


class _ShardState:
    """One shard's decoder + protocol bookkeeping inside a ShardedSession."""

    __slots__ = ("decoder", "remote_items")

    def __init__(self, decoder: StreamDecoder):
        self.decoder = decoder
        self.remote_items: int | None = None


class ShardedSession:
    """Incremental reconciliation of a sharded local set against a
    :class:`ShardedStream`, one decoder per shard, one batched device
    decode per grow step.

    Parameters
    ----------
    local: the local side as a :class:`ShardedStream` (each shard's encoder
        is subtracted from the matching remote shard), or None to decode S
        raw shard streams (recovers the remote sets themselves).
    n_shards, nbytes, key: partition geometry — inferred from ``local``
        when given.  Both ends must agree on all three (the wire payload
        carries ``n_shards`` and each frame carries ``nbytes``; mismatches
        raise :class:`~repro.protocol.session.ProtocolError`).
    pacing: per-shard window schedule.  Policies are stateless (a pure
        function of that shard's progress), so one instance drives all
        shards independently; default is the session-standard doubling
        schedule.
    max_m: abort bound on any single shard's stream consumption.
    backend: "host" | "device" | "auto".  "device" decodes all shards that
        received symbols in ONE :func:`repro.kernels.ops.decode_device_batched`
        call per grow step; a shard whose ``max_diff`` overflows falls back
        to the exact host peel for that shard only.
    max_diff: per-shard bound on the device decoder's fixed recovered-item
        buffers (sharding divides the difference ~uniformly, so this can be
        ~d/S plus slack rather than d).

    Invariants: windows must arrive in order per shard (overlap with
    already-consumed symbols is trimmed, gaps raise); each shard terminates
    on its own ρ(0)=1 signal; ``decoded`` is the conjunction over shards.
    """

    def __init__(self, local: ShardedStream | None = None,
                 n_shards: int | None = None, nbytes: int | None = None,
                 pacing: Pacing | None = None, key=None,
                 max_m: int = 1 << 22, backend: str = "host",
                 max_diff: int | None = None):
        if local is not None:
            n_shards = local.n_shards if n_shards is None else n_shards
            nbytes = local.nbytes if nbytes is None else nbytes
            key = local.key if key is None else key
            if n_shards != local.n_shards:
                raise ValueError(f"n_shards={n_shards} but local partition "
                                 f"has {local.n_shards}")
        if n_shards is None or nbytes is None:
            raise ValueError("need n_shards and nbytes (or a local "
                             "ShardedStream to infer them from)")
        key = DEFAULT_KEY if key is None else key
        self.n_shards = n_shards
        self.nbytes = nbytes
        self.key = key
        self.pacing = pacing or Exponential(block=8, growth=2.0)
        self.max_m = max_m
        self.backend = resolve_backend(backend)
        self.max_diff = max_diff
        self.bytes_received = 0
        self.grow_steps = 0
        # per-shard decoders peel on the host; THIS session owns the
        # device path so all shards batch into one dispatch
        self._shards = [
            _ShardState(StreamDecoder(
                nbytes, local=local.shards[s].encoder if local else None,
                key=key, backend="host"))
            for s in range(n_shards)]

    # -- state --------------------------------------------------------------
    def set_backend(self, backend: str) -> None:
        """Switch the decode engine; safe between grow steps (both engines
        maintain identical per-shard decoder state)."""
        self.backend = resolve_backend(backend)

    @property
    def decoded(self) -> bool:
        """True once every shard has hit its ρ(0)=1 termination signal."""
        return all(st.decoder.decoded for st in self._shards)

    @property
    def symbols_received(self) -> int:
        return sum(st.decoder.symbols_received for st in self._shards)

    # -- pull protocol ------------------------------------------------------
    def requests(self) -> list[tuple[int, int, int]]:
        """Next window [lo, hi) per still-undecoded shard; [] when done.

        Each shard's window size comes from the shared pacing policy
        applied to *that shard's* progress — settled shards drop out of the
        list, hot shards keep growing.  Raises ``RuntimeError`` if any
        shard exceeds ``max_m`` without decoding.
        """
        reqs = []
        for s, st in enumerate(self._shards):
            if st.decoder.decoded:
                continue
            lo = st.decoder.symbols_received
            if lo >= self.max_m:
                raise RuntimeError(f"shard {s} did not converge within "
                                   f"{self.max_m} symbols")
            reqs.append((s, lo, min(lo + self.pacing.next_take(lo),
                                    self.max_m)))
        return reqs

    def offer_payload(self, data: bytes) -> bool:
        """Consume one merged wire payload (all shards' frames), then run
        ONE batched decode over every shard that received symbols.
        Returns ``decoded``."""
        n_shards, frames = decode_shard_frames(data)
        if n_shards != self.n_shards:
            raise ProtocolError(f"partition mismatch: payload has "
                                f"{n_shards} shards, session {self.n_shards}")
        self.bytes_received += len(data)
        windows = []
        for shard_id, sym, n_items, start in frames:
            self._shards[shard_id].remote_items = n_items
            windows.append((shard_id, sym, start))
        return self.offer_windows(windows)

    def offer_windows(self, windows) -> bool:
        """Feed ``(shard, symbols, start)`` windows (the in-process peer of
        :meth:`offer_payload`), absorbing every window first and then
        decoding all touched shards in one batched step.  Validation is
        all-or-nothing: every window is checked (shard id, order,
        geometry) before ANY state mutates, so a rejected round can be
        corrected and retried without losing symbols.  Returns
        ``decoded``."""
        # pass 1: validate the whole round against simulated per-shard
        # positions (a round may carry several windows for one shard)
        have = {}
        accepted = []       # (shard, trimmed symbols) in arrival order
        for shard_id, sym, start in windows:
            if not 0 <= shard_id < self.n_shards:
                raise ProtocolError(f"shard_id {shard_id} outside "
                                    f"[0, {self.n_shards})")
            pos = have.setdefault(
                shard_id, self._shards[shard_id].decoder.symbols_received)
            if start > pos:
                raise ProtocolError(f"shard {shard_id} gap: expected window "
                                    f"at {pos}, got {start}")
            if sym.nbytes != self.nbytes:
                raise ProtocolError(f"geometry mismatch: ℓ={sym.nbytes}, "
                                    f"session ℓ={self.nbytes}")
            if start < pos:
                if start + sym.m <= pos:
                    continue                      # wholly stale window
                sym = sym.window(pos - start)
            have[shard_id] = pos + sym.m
            accepted.append((shard_id, sym))
        # pass 2: absorb (decoder positions evolve exactly as simulated)
        absorbed = [(shard_id, *self._shards[shard_id].decoder.absorb(sym))
                    for shard_id, sym in accepted]
        if absorbed:
            self.grow_steps += 1
            if self.backend == "device":
                self._decode_batched(absorbed)
            else:
                for shard_id, old, m in absorbed:
                    self._shards[shard_id].decoder.peel_window(old, m)
        for shard_id, _, _ in absorbed:
            self._shards[shard_id].decoder.mark_decoded()
        return self.decoded

    def _decode_batched(self, absorbed) -> None:
        """One ``decode_device_batched`` dispatch over every absorbed
        shard's residual; per-shard overflow falls back to the host peel
        for that shard alone."""
        from repro.kernels.ops import decode_device_batched
        decs = [self._shards[s].decoder for s, _, _ in absorbed]
        results = decode_device_batched(
            [d.work for d in decs], nbytes=self.nbytes, key=self.key,
            max_diff=self.max_diff)
        for (shard_id, old, m), dec, res in zip(absorbed, decs, results):
            if res.overflow:
                dec.peel_window(old, m)
            else:
                dec.merge_device_result(res)

    # -- outcome ------------------------------------------------------------
    def result(self):
        """(only_remote, only_local) uint32 word arrays, shards merged."""
        rem = [st.decoder.result()[0] for st in self._shards]
        loc = [st.decoder.result()[1] for st in self._shards]
        return np.concatenate(rem), np.concatenate(loc)

    def report(self) -> ShardedReport:
        per_shard = []
        for s, st in enumerate(self._shards):
            only_remote, only_local = st.decoder.result()
            per_shard.append(ShardReport(
                shard=s, only_remote=only_remote, only_local=only_local,
                symbols_used=st.decoder.decoded_at or
                st.decoder.symbols_received,
                symbols_received=st.decoder.symbols_received,
                remote_items=st.remote_items))
        counts = [sr.remote_items for sr in per_shard]
        return ShardedReport(
            shards=per_shard,
            only_remote=np.concatenate([sr.only_remote for sr in per_shard]),
            only_local=np.concatenate([sr.only_local for sr in per_shard]),
            nbytes=self.nbytes,
            symbols_used=sum(sr.symbols_used for sr in per_shard),
            symbols_received=sum(sr.symbols_received for sr in per_shard),
            bytes_received=self.bytes_received,
            remote_items=None if any(c is None for c in counts)
            else sum(counts),
            grow_steps=self.grow_steps)


def run_sharded_session(stream: ShardedStream, session: ShardedSession,
                        wire: bool = True,
                        backend: str | None = None) -> ShardedReport:
    """Drive ``session`` to completion against a :class:`ShardedStream`.

    Each round trip gathers every undecoded shard's window request, answers
    all of them with one merged payload (``wire=True``, the native sharded
    mode — exactly the bytes two networked peers exchange) or with
    in-process zero-copy windows (``wire=False``), and hands them to the
    session, which decodes all touched shards in one batched step.
    ``backend`` switches the session's engine first, like
    :meth:`ShardedSession.set_backend`, and persists afterwards.

    Both ends must run the identical partition: mixed shard counts would
    silently mis-reconcile in-process (the wire path carries S in the
    payload header), so the driver rejects them up front.
    """
    if stream.n_shards != session.n_shards:
        raise ProtocolError(f"partition mismatch: stream has "
                            f"{stream.n_shards} shards, session "
                            f"{session.n_shards}")
    if backend is not None:
        session.set_backend(backend)
    while True:
        reqs = session.requests()
        if not reqs:
            break
        if wire:
            session.offer_payload(stream.payload(reqs))
        else:
            session.offer_windows(
                [(s, stream.window(s, lo, hi), lo) for s, lo, hi in reqs])
    return session.report()
