"""Session — one peer's half of a rateless reconciliation (paper §4.1).

A ``Session`` replaces the three grow-and-peel loops that used to be
hand-rolled in ``reconcile_sets``, ``checkpoint/reconcile.py`` and
``examples/multi_peer_sync.py``.  It owns

* a :class:`~repro.core.stream.StreamDecoder` (subtracts the local set's
  symbols index-wise, peels incrementally, terminates the moment symbol 0
  empties — the ρ(0)=1 signal);
* a :class:`~repro.protocol.pacing.Pacing` policy deciding how much more of
  the remote universal stream to pull per round trip;
* window bookkeeping: the remote stream is consumed as contiguous windows,
  either as in-process :class:`CodedSymbols` views (``offer``) or as wire
  byte frames (``offer_bytes``) — a session produces and consumes *bytes*,
  not numpy internals, when run in wire mode.

Pull protocol::

    while (win := session.request()) is not None:
        lo, hi = win
        session.offer_bytes(stream.frames(lo, hi))   # or offer(window, lo)
    report = session.report()

:func:`run_session` packages that loop.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hashing import DEFAULT_KEY, words_to_bytes
from repro.core.stream import StreamDecoder
from repro.core.symbols import CodedSymbols
from repro.core.wire import decode_frames

from .pacing import Exponential, Pacing
from .stream import SymbolStream


class ProtocolError(RuntimeError):
    """A window arrived out of order / with inconsistent geometry."""


@dataclasses.dataclass
class SessionReport:
    """Outcome of a completed session."""
    only_remote: np.ndarray   # (r, L) uint32 words — items only in remote set
    only_local: np.ndarray    # (s, L) uint32 words — items only in local set
    nbytes: int               # item length ℓ
    symbols_used: int         # stream prefix length at the decode signal
    symbols_received: int     # including pacing overshoot
    bytes_received: int       # wire-mode traffic (0 for in-process sessions)
    remote_items: int | None  # |remote set|, learned from frame headers

    def only_remote_bytes(self) -> np.ndarray:
        """(r, ℓ) uint8 — remote-exclusive items as raw bytes."""
        return words_to_bytes(self.only_remote, self.nbytes)

    def only_local_bytes(self) -> np.ndarray:
        return words_to_bytes(self.only_local, self.nbytes)

    def overhead(self, d: int | None = None) -> float:
        """symbols_used / d (defaults to the recovered difference size)."""
        if d is None:
            d = self.only_remote.shape[0] + self.only_local.shape[0]
        return self.symbols_used / max(d, 1)


class Session:
    """Incremental reconciliation of one local set against a remote stream.

    Parameters
    ----------
    local: Encoder/Sketch of the local set, or None to decode a raw stream
        (recovers the remote set itself rather than a difference).
    nbytes, key: stream geometry — inferred from ``local`` when given.
    pacing: window schedule (default: the doubling schedule the old
        ``reconcile_sets`` loop used).
    max_m: abort bound on stream consumption.
    backend: "host" | "device" | "auto" peel engine (see
        :mod:`repro.core.decoder`); "device" wave-peels each window through
        the Pallas decoder, with host fallback on ``max_diff`` overflow.
    max_diff: recovered-item buffer bound for the device engine.
    """

    def __init__(self, local=None, nbytes: int | None = None,
                 pacing: Pacing | None = None, key=None,
                 max_m: int = 1 << 22, backend: str = "host",
                 max_diff: int | None = None):
        if local is not None:
            nbytes = local.nbytes if nbytes is None else nbytes
            key = local.key if key is None else key
        if nbytes is None:
            raise ValueError("need nbytes (or a local set to infer it from)")
        key = DEFAULT_KEY if key is None else key
        self.nbytes = nbytes
        self.pacing = pacing or Exponential(block=8, growth=2.0)
        self.max_m = max_m
        self.decoder = StreamDecoder(nbytes, local=local, key=key,
                                     backend=backend, max_diff=max_diff)
        self.bytes_received = 0
        self.remote_items: int | None = None

    # -- state --------------------------------------------------------------
    @property
    def backend(self) -> str:
        return self.decoder.backend

    def set_backend(self, backend: str) -> None:
        """Switch the peel engine; safe between windows (both engines keep
        the identical decoder state)."""
        from repro.core.decoder import resolve_backend
        self.decoder.backend = resolve_backend(backend)

    @property
    def decoded(self) -> bool:
        return self.decoder.decoded

    @property
    def symbols_received(self) -> int:
        return self.decoder.symbols_received

    @property
    def symbols_used(self) -> int | None:
        return self.decoder.decoded_at

    # -- pull protocol ------------------------------------------------------
    def request(self) -> tuple[int, int] | None:
        """Next stream window [lo, hi) this session wants; None if done.

        ``lo`` is always the current stream position (windows are
        contiguous) and ``hi − lo`` comes from the pacing policy, clamped
        to ``max_m``.  Raises ``RuntimeError`` once ``max_m`` symbols have
        been consumed without decoding — the reconciliation is diverging
        (wrong key, corrupted stream, or a difference beyond the bound).
        """
        if self.decoded:
            return None
        lo = self.symbols_received
        if lo >= self.max_m:
            raise RuntimeError(
                f"reconciliation did not converge within {self.max_m} symbols")
        return lo, min(lo + self.pacing.next_take(lo), self.max_m)

    def offer(self, sym: CodedSymbols, start: int = 0) -> bool:
        """Feed stream symbols [start, start+sym.m) as in-process views.

        Invariants: windows arrive in order (``start`` past the current
        position raises :class:`ProtocolError` — the stream has no gaps);
        overlap with already-consumed symbols is trimmed, wholly stale
        windows are no-ops; the window's item length must match the
        session's.  The symbols are copied before peeling, so zero-copy
        stream views may be passed directly.  Returns ``decoded``.
        """
        have = self.symbols_received
        if start > have:
            raise ProtocolError(f"gap: expected window at {have}, got {start}")
        if sym.nbytes != self.nbytes:
            raise ProtocolError(f"geometry mismatch: ℓ={sym.nbytes}, "
                                f"session ℓ={self.nbytes}")
        if start < have:
            if start + sym.m <= have:
                return self.decoded          # wholly stale window
            sym = sym.window(have - start)
        return self.decoder.receive(sym)

    def offer_bytes(self, data: bytes) -> bool:
        """Feed one wire frame (:func:`repro.core.wire.encode_frames`
        output).  The frame is self-describing — its header carries the
        window start and the remote set size, which is recorded on
        :attr:`remote_items` — then :meth:`offer` rules apply.  Returns
        ``decoded``."""
        sym, n_items, start = decode_frames(data)
        self.bytes_received += len(data)
        self.remote_items = n_items
        return self.offer(sym, start)

    # -- outcome ------------------------------------------------------------
    def result(self):
        """(only_remote, only_local) as uint32 word arrays."""
        return self.decoder.result()

    def report(self) -> SessionReport:
        """Snapshot the session outcome as a :class:`SessionReport`.

        Valid at any time: before decode it reports the partial recovery
        (``symbols_used`` then falls back to ``symbols_received``); after
        decode it is the final reconciliation result.
        """
        only_remote, only_local = self.decoder.result()
        return SessionReport(
            only_remote=only_remote, only_local=only_local,
            nbytes=self.nbytes,
            symbols_used=self.symbols_used or self.symbols_received,
            symbols_received=self.symbols_received,
            bytes_received=self.bytes_received,
            remote_items=self.remote_items)


def run_session(stream: SymbolStream, session: Session,
                wire: bool = False,
                backend: str | None = None) -> SessionReport:
    """Drive ``session`` to completion against ``stream``.

    Parameters
    ----------
    stream: the remote side — a :class:`SymbolStream` (or, with a
        :class:`~repro.protocol.sharded.ShardedSession`, a
        :class:`~repro.protocol.sharded.ShardedStream`; sharded pairs are
        dispatched to :func:`~repro.protocol.sharded.run_sharded_session`).
    session: the local side; drained via its pull protocol until decoded.
    wire: route every window through the byte-level frame codec — exactly
        what two networked peers would exchange.  ``False`` serves
        zero-copy in-process windows instead.
    backend: optionally switch the session's peel engine ("host" |
        "device" | "auto") before driving it; like
        :meth:`Session.set_backend`, the switch persists on the session
        afterwards.

    Returns the session's report (:class:`SessionReport`, or
    :class:`~repro.protocol.sharded.ShardedReport` for sharded pairs).
    """
    from .sharded import ShardedSession, run_sharded_session
    if isinstance(session, ShardedSession):
        return run_sharded_session(stream, session, wire=wire,
                                   backend=backend)
    if backend is not None:
        session.set_backend(backend)
    while True:
        win = session.request()
        if win is None:
            break
        lo, hi = win
        if wire:
            session.offer_bytes(stream.frames(lo, hi))
        else:
            session.offer(stream.window(lo, hi), lo)
    return session.report()
