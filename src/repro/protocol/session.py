"""Session — one peer's half of a rateless reconciliation (paper §4.1).

A ``Session`` replaces the three grow-and-peel loops that used to be
hand-rolled in ``reconcile_sets``, ``checkpoint/reconcile.py`` and
``examples/multi_peer_sync.py``.  It is a thin single-peer wrapper over
the :mod:`engine <repro.protocol.engine>`'s :class:`~repro.protocol.engine.PeerState`
— one decode unit, plus

* a :class:`~repro.core.stream.StreamDecoder` (subtracts the local set's
  symbols index-wise, peels incrementally, terminates the moment symbol 0
  empties — the ρ(0)=1 signal);
* a :class:`~repro.protocol.pacing.Pacing` policy deciding how much more of
  the remote universal stream to pull per round trip;
* window bookkeeping: the remote stream is consumed as contiguous windows,
  either as in-process :class:`CodedSymbols` views (``offer``) or as wire
  byte frames (``offer_bytes``) — a session produces and consumes *bytes*,
  not numpy internals, when run in wire mode.

Pull protocol::

    while (win := session.request()) is not None:
        lo, hi = win
        session.offer_bytes(stream.frames(lo, hi))   # or offer(window, lo)
    report = session.report()

:func:`run_session` packages that loop (on a single-peer, non-pipelined
:class:`~repro.protocol.engine.ReconcileEngine`); to reconcile against
many peers at once — shared ticks, cross-peer batched decode, ingest/
decode overlap — register several sessions on one engine instead.
"""
from __future__ import annotations

from repro.core.hashing import DEFAULT_KEY
from repro.core.symbols import CodedSymbols

from .engine import (PeerState, ProtocolError, execute_round, ingest_frames,
                     offer_round)
from .pacing import Exponential, Pacing
from .reports import SessionReport, build_session_report
from .stream import SymbolStream

__all__ = ["ProtocolError", "Session", "SessionReport", "run_session"]


class Session:
    """Incremental reconciliation of one local set against a remote stream.

    Parameters
    ----------
    local: Encoder/Sketch of the local set, or None to decode a raw stream
        (recovers the remote set itself rather than a difference).
    nbytes, key: stream geometry — inferred from ``local`` when given.
    pacing: window schedule (default: the doubling schedule the old
        ``reconcile_sets`` loop used).
    max_m: abort bound on stream consumption.
    backend: "host" | "device" | "auto" peel engine (see
        :mod:`repro.core.decoder`); "device" wave-peels each window through
        the kernels' batched decode path, with host fallback on
        ``max_diff`` overflow.
    max_diff: recovered-item buffer bound for the device engine.
    """

    def __init__(self, local=None, nbytes: int | None = None,
                 pacing: Pacing | None = None, key=None,
                 max_m: int = 1 << 22, backend: str = "host",
                 max_diff: int | None = None):
        if local is not None:
            nbytes = local.nbytes if nbytes is None else nbytes
            key = local.key if key is None else key
        if nbytes is None:
            raise ValueError("need nbytes (or a local set to infer it from)")
        key = DEFAULT_KEY if key is None else key
        self.nbytes = nbytes
        self._peer = PeerState(
            nbytes=nbytes, key=key, locals_=[local],
            pacing=pacing or Exponential(block=8, growth=2.0),
            max_m=max_m, backend=backend, max_diff=max_diff, sharded=False)
        self.decoder = self._peer.units[0].decoder

    # -- state --------------------------------------------------------------
    @property
    def backend(self) -> str:
        return self._peer.backend

    def set_backend(self, backend: str) -> None:
        """Switch the peel engine; safe between windows (both engines keep
        the identical decoder state)."""
        self._peer.set_backend(backend)

    @property
    def pacing(self) -> Pacing:
        return self._peer.pacing

    @pacing.setter
    def pacing(self, pacing: Pacing) -> None:
        self._peer.pacing = pacing

    @property
    def max_m(self) -> int:
        return self._peer.max_m

    @property
    def bytes_received(self) -> int:
        return self._peer.bytes_received

    @property
    def remote_items(self) -> int | None:
        return self._peer.units[0].remote_items

    @property
    def decoded(self) -> bool:
        return self.decoder.decoded

    @property
    def symbols_received(self) -> int:
        return self.decoder.symbols_received

    @property
    def symbols_used(self) -> int | None:
        return self.decoder.decoded_at

    # -- pull protocol ------------------------------------------------------
    def request(self) -> tuple[int, int] | None:
        """Next stream window [lo, hi) this session wants; None if done.

        ``lo`` is always the current stream position (windows are
        contiguous) and ``hi − lo`` comes from the pacing policy, clamped
        to ``max_m``.  Raises ``RuntimeError`` once ``max_m`` symbols have
        been consumed without decoding — the reconciliation is diverging
        (wrong key, corrupted stream, or a difference beyond the bound).
        """
        reqs = self._peer.requests()
        if not reqs:
            return None
        (_, lo, hi), = reqs
        return lo, hi

    def offer(self, sym: CodedSymbols, start: int = 0) -> bool:
        """Feed stream symbols [start, start+sym.m) as in-process views.

        Invariants: windows arrive in order (``start`` past the current
        position raises :class:`ProtocolError` — the stream has no gaps);
        overlap with already-consumed symbols is trimmed, wholly stale
        windows are no-ops; the window's item length must match the
        session's.  The symbols are copied before peeling, so zero-copy
        stream views may be passed directly.  Returns ``decoded``.
        """
        return offer_round(self._peer, [(0, sym, start)])

    def offer_bytes(self, data: bytes) -> bool:
        """Feed one wire frame (:func:`repro.core.wire.encode_frames`
        output).  The frame is self-describing — its header carries the
        window start and the remote set size, which is recorded on
        :attr:`remote_items` — then :meth:`offer` rules apply.  Returns
        ``decoded``."""
        execute_round(ingest_frames(self._peer, data))
        return self.decoded

    # -- outcome ------------------------------------------------------------
    def result(self):
        """(only_remote, only_local) as uint32 word arrays."""
        return self.decoder.result()

    def report(self) -> SessionReport:
        """Snapshot the session outcome as a :class:`SessionReport`.

        Valid at any time: before decode it reports the partial recovery
        (``symbols_used`` then falls back to ``symbols_received``); after
        decode it is the final reconciliation result.
        """
        return build_session_report(self._peer)


def run_session(stream: SymbolStream, session: Session,
                wire: bool = False,
                backend: str | None = None) -> SessionReport:
    """Drive ``session`` to completion against ``stream``.

    Parameters
    ----------
    stream: the remote side — a :class:`SymbolStream` (or, with a
        :class:`~repro.protocol.sharded.ShardedSession`, a
        :class:`~repro.protocol.sharded.ShardedStream`; sharded pairs are
        dispatched to :func:`~repro.protocol.sharded.run_sharded_session`).
    session: the local side; drained via its pull protocol until decoded.
    wire: route every window through the byte-level frame codec — exactly
        what two networked peers would exchange.  ``False`` serves
        zero-copy in-process windows instead.
    backend: optionally switch the session's peel engine ("host" |
        "device" | "auto") before driving it; like
        :meth:`Session.set_backend`, the switch persists on the session
        afterwards.

    The loop itself is one single-peer, non-pipelined
    :class:`~repro.protocol.engine.ReconcileEngine` — the exact serial
    request → offer → decode lockstep.  Returns the session's report
    (:class:`SessionReport`, or
    :class:`~repro.protocol.reports.ShardedReport` for sharded pairs).
    """
    from .engine import serve
    from .sharded import ShardedSession, run_sharded_session
    if isinstance(session, ShardedSession):
        return run_sharded_session(stream, session, wire=wire,
                                   backend=backend)
    return serve([(stream, session)], wire=wire, backend=backend,
                 pipeline=False)[0]
