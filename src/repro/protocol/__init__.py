"""Session-oriented reconciliation protocol (paper §4.1 universality, §6).

One :class:`SymbolStream` per set — it serves zero-copy windows or wire
byte frames of the universal coded-symbol stream to any number of
:class:`Session` peers, each with its own :mod:`pacing <repro.protocol.pacing>`
policy.  See ``examples/quickstart.py`` and ``examples/multi_peer_sync.py``.
"""
from .pacing import Exponential, FixedBlock, LineRate, Pacing
from .session import (ProtocolError, Session, SessionReport, run_session)
from .stream import SymbolStream

__all__ = [
    "Exponential", "FixedBlock", "LineRate", "Pacing", "ProtocolError",
    "Session", "SessionReport", "SymbolStream", "run_session",
]
