"""Session-oriented reconciliation protocol (paper §4.1 universality, §6).

One :class:`SymbolStream` per set — it serves zero-copy windows or wire
byte frames of the universal coded-symbol stream to any number of
:class:`Session` peers, each with its own :mod:`pacing <repro.protocol.pacing>`
policy.  For datacenter-scale fan-out, :class:`ShardedStream` /
:class:`ShardedSession` hash-partition the key space into S shards served
as merged wire payloads, and a :class:`ReconcileEngine` drives any number
of concurrent peers through one event-driven plan/execute loop: pending
(peer, shard, window) decode units coalesce into ONE batched device
dispatch per shape bucket per tick, and device decode overlaps host frame
ingest (double-buffering).  See ``examples/quickstart.py``,
``examples/multi_peer_sync.py`` and ``examples/sharded_sync.py``; the
layer map lives in ``docs/ARCHITECTURE.md`` and the byte formats in
``docs/WIRE_FORMAT.md``.
"""
from .engine import (DecodePlan, PeerState, ProtocolError, ReconcileEngine,
                     serve)
from .pacing import Exponential, FixedBlock, LineRate, Pacing
from .reports import (SessionReport, ShardReport, ShardedReport)
from .session import Session, run_session
from .sharded import (ShardedSession, ShardedStream, run_sharded_session,
                      shard_of)
from .stream import SymbolStream

__all__ = [
    "DecodePlan", "Exponential", "FixedBlock", "LineRate", "Pacing",
    "PeerState", "ProtocolError", "ReconcileEngine", "Session",
    "SessionReport", "ShardReport", "ShardedReport", "ShardedSession",
    "ShardedStream", "SymbolStream", "run_session", "run_sharded_session",
    "serve", "shard_of",
]
