"""Pacing policies: how much more of the universal stream a session pulls.

The stream is infinite and any prefix decodes once it is long enough
(paper §4.1), so pacing only trades *overshoot* (symbols received past the
minimal decodable prefix) against *round trips*.  The three policies here
cover the shapes the repo's former hand-rolled grow-loops used, plus the
paper's §6 deployment model:

* :class:`FixedBlock` — constant window; overshoot ≤ block − 1, most round
  trips.  What ``examples/multi_peer_sync.py`` hand-rolled.
* :class:`Exponential` — window grows with the amount already sent;
  O(log d) round trips, overshoot ≤ (growth − 1)·m.  ``growth=2`` is the
  old ``reconcile_sets`` loop (take = max(block, m)); ``growth=1.5`` is the
  old ``sync_from_peer`` loop (step = max(block, m // 2)).
* :class:`LineRate` — the paper's §6 schedule: the sender streams symbols
  continuously at line rate and the receiver ACKs termination, so one
  bandwidth-delay product of symbols is always in flight.  Pull-model
  equivalent: every window is ⌈BDP⌉ symbols; overshoot is bounded by the
  BDP regardless of the difference size.

Policies are **stateless**: :meth:`Pacing.next_take` is a pure function of
the symbols already pulled, so one instance can drive any number of
sessions — or every (peer, shard) decode unit of a multi-peer
:class:`~repro.protocol.engine.ReconcileEngine`, where it is applied to
each unit's own progress independently.  Statelessness is also what lets
the engine's double-buffered tick loop compute the *next* round's
requests while the previous round's decode is still in flight: the
request depends only on the unit's stream position, never on the decode
outcome.
"""
from __future__ import annotations

import math


class Pacing:
    """Policy interface: next window size given symbols already pulled.

    Subclasses implement :meth:`next_take` as a pure (stateless) function;
    sessions call it with their current stream position before every
    request and pull exactly that many further symbols.
    """

    def next_take(self, m_sent: int) -> int:
        """Symbols to request next, given ``m_sent`` already pulled.

        Must return ≥ 1 (a session that is not decoded always needs more
        of the stream).
        """
        raise NotImplementedError

    def next_window(self, lo: int, max_m: int) -> tuple[int, int]:
        """The next stream window ``[lo, hi)`` for a unit at position
        ``lo``, clamped to the ``max_m`` consumption bound — the one
        request shape sessions and the engine both speak.

        >>> FixedBlock(8).next_window(16, 20)
        (16, 20)
        """
        return lo, min(lo + self.next_take(lo), max_m)


class FixedBlock(Pacing):
    """Constant ``block``-symbol windows.

    Minimal overshoot (≤ block − 1 symbols past the decodable prefix), one
    round trip per block — the most chatty and the most byte-frugal
    schedule.

    >>> [FixedBlock(5).next_take(m) for m in (0, 5, 80)]
    [5, 5, 5]
    """

    def __init__(self, block: int = 8):
        assert block >= 1
        self.block = block

    def next_take(self, m_sent: int) -> int:
        return self.block

    def __repr__(self):
        return f"FixedBlock({self.block})"


class Exponential(Pacing):
    """Windows growing ∝ the prefix already pulled.

    ``next_take(m) = max(block, ⌊m·(growth − 1)⌋)``: O(log d) round trips
    at the price of up to (growth − 1)·m overshoot.

    >>> exp = Exponential(block=8, growth=2.0)    # the doubling schedule
    >>> [exp.next_take(m) for m in (0, 8, 16, 100)]
    [8, 8, 16, 100]
    >>> Exponential(block=16, growth=1.5).next_take(64)
    32
    """

    def __init__(self, block: int = 8, growth: float = 2.0):
        assert block >= 1 and growth > 1.0
        self.block = block
        self.growth = growth

    def next_take(self, m_sent: int) -> int:
        return max(self.block, int(m_sent * (self.growth - 1.0)))

    def __repr__(self):
        return f"Exponential(block={self.block}, growth={self.growth})"


class LineRate(Pacing):
    """Paper §6: continuous streaming with a termination ACK one RTT away.

    ``bandwidth`` is in symbols/second (divide link bytes/s by the wire
    size ℓ + 8 + ~1 of one symbol); the in-flight window is
    ``bandwidth · rtt`` symbols, so overshoot is bounded by the BDP
    regardless of the difference size.

    >>> LineRate(bandwidth=1000, rtt=0.05).next_take(0)
    50
    """

    def __init__(self, bandwidth: float, rtt: float):
        assert bandwidth > 0 and rtt > 0
        self.bdp = max(1, math.ceil(bandwidth * rtt))

    def next_take(self, m_sent: int) -> int:
        return self.bdp

    def __repr__(self):
        return f"LineRate(bdp={self.bdp})"
