"""Pacing policies: how much more of the universal stream a session pulls.

The stream is infinite and any prefix decodes once it is long enough
(paper §4.1), so pacing only trades *overshoot* (symbols received past the
minimal decodable prefix) against *round trips*.  The three policies here
cover the shapes the repo's former hand-rolled grow-loops used, plus the
paper's §6 deployment model:

* :class:`FixedBlock` — constant window; overshoot ≤ block − 1, most round
  trips.  What ``examples/multi_peer_sync.py`` hand-rolled.
* :class:`Exponential` — window grows with the amount already sent;
  O(log d) round trips, overshoot ≤ (growth − 1)·m.  ``growth=2`` is the
  old ``reconcile_sets`` loop (take = max(block, m)); ``growth=1.5`` is the
  old ``sync_from_peer`` loop (step = max(block, m // 2)).
* :class:`LineRate` — the paper's §6 schedule: the sender streams symbols
  continuously at line rate and the receiver ACKs termination, so one
  bandwidth-delay product of symbols is always in flight.  Pull-model
  equivalent: every window is ⌈BDP⌉ symbols; overshoot is bounded by the
  BDP regardless of the difference size.
"""
from __future__ import annotations

import math


class Pacing:
    """Policy interface: next window size given symbols already pulled."""

    def next_take(self, m_sent: int) -> int:
        raise NotImplementedError


class FixedBlock(Pacing):
    def __init__(self, block: int = 8):
        assert block >= 1
        self.block = block

    def next_take(self, m_sent: int) -> int:
        return self.block

    def __repr__(self):
        return f"FixedBlock({self.block})"


class Exponential(Pacing):
    def __init__(self, block: int = 8, growth: float = 2.0):
        assert block >= 1 and growth > 1.0
        self.block = block
        self.growth = growth

    def next_take(self, m_sent: int) -> int:
        return max(self.block, int(m_sent * (self.growth - 1.0)))

    def __repr__(self):
        return f"Exponential(block={self.block}, growth={self.growth})"


class LineRate(Pacing):
    """Paper §6: continuous streaming with a termination ACK one RTT away.

    ``bandwidth`` is in symbols/second (divide link bytes/s by the wire
    size ℓ + 8 + ~1 of one symbol); the in-flight window is
    ``bandwidth · rtt`` symbols.
    """

    def __init__(self, bandwidth: float, rtt: float):
        assert bandwidth > 0 and rtt > 0
        self.bdp = max(1, math.ceil(bandwidth * rtt))

    def next_take(self, m_sent: int) -> int:
        return self.bdp

    def __repr__(self):
        return f"LineRate(bdp={self.bdp})"
