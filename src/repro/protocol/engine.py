"""Reconciliation engine — one event-driven core serving N concurrent peers.

The paper's north-star deployment (§7, Ethereum state sync) is a node
reconciling against *many* peers at once.  Before this module each
``Session``/``ShardedSession`` owned its own grow loop, so N concurrent
peers meant N separate device dispatches per round and a fully serial
ingest → decode → request cycle.  The engine restates reconciliation as an
event loop with an explicit **plan/execute split**:

* **plan** — each tick, pending work from every registered peer is
  collected into a :class:`DecodePlan` of ``(peer, shard, window)``
  :class:`DecodeUnit`\\ s and coalesced by *shape bucket* (tile-padded
  prefix length, item geometry, session key, ``max_diff`` bound);
* **execute** — each bucket becomes ONE
  :func:`repro.kernels.ops.decode_device_batched` dispatch: the peel wave
  ``vmap``-ed over a ragged peer×shard unit axis with per-unit prefix
  lengths as traced data.  This generalizes the sharded session's
  cross-*shard* batching to cross-*peer* batching — 8 peers × 4 shards at
  the same pacing is still one device program per tick;
* **double-buffering** — with ``pipeline=True`` the device peels tick t's
  buckets as a JAX async dispatch (:class:`PendingRound`, polled
  non-blockingly) while the host absorbs tick t+1's frames and computes
  the next window requests from the stateless pacing policies.  Decode
  results merge *behind* the newly absorbed symbols
  (:meth:`repro.core.stream.StreamDecoder.merge_device_result` is
  tail-aware), and ``decoded_at`` is pinned to the prefix length the
  successful decode actually covered, so pipelining never inflates the
  reported overhead.

``Session`` and ``ShardedSession`` are thin single-peer wrappers over this
module: their ``offer``/``offer_windows`` paths delegate to
:func:`absorb_round` + :func:`execute_round`, so the grow-loop, overflow
fallback, termination and accounting logic live exactly once.  A unit
whose device decode overflows ``max_diff`` falls back to the exact host
peel and is **pinned to the host** from then on — re-dispatching a known
oversized residual to the device (e.g. after a mid-session
``set_backend``) would only buy another overflow.

Pull protocol, multi-peer::

    engine = ReconcileEngine()
    for stream, session in peers:
        engine.register(stream, session, wire=True)
    reports = engine.run()

:func:`run_session` / :func:`run_sharded_session` delegate their single
pair to a non-pipelined engine, which reproduces the legacy lockstep
trajectory exactly.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.core.decoder import resolve_backend
from repro.core.stream import StreamDecoder
from repro.core.wire import decode_frames, decode_shard_frames


class ProtocolError(RuntimeError):
    """A window arrived out of order / with inconsistent geometry."""


# ---------------------------------------------------------------------------
# Peer state: decode units + pacing + accounting, shared by every wrapper.
# ---------------------------------------------------------------------------
class UnitState:
    """One (peer, shard) decode unit: an incremental decoder plus its
    protocol bookkeeping.  ``pinned_host`` is set the first time a device
    decode of this unit overflows ``max_diff`` — from then on the unit
    peels on the host even if the peer's backend is (re)set to device."""

    __slots__ = ("shard", "decoder", "remote_items", "pinned_host")

    def __init__(self, shard: int, decoder: StreamDecoder):
        self.shard = shard
        self.decoder = decoder
        self.remote_items: int | None = None
        self.pinned_host = False


class PeerState:
    """Everything the engine knows about one registered peer.

    Owns the per-shard :class:`UnitState`\\ s (a plain session is the
    S=1 special case), the pacing policy, the backend/``max_diff`` decode
    configuration, and the wire accounting.  Wrappers keep a ``PeerState``
    as their single source of truth; a :class:`ReconcileEngine` drives any
    number of them through one shared plan/execute loop.
    """

    def __init__(self, *, nbytes: int, key, locals_, pacing, max_m: int,
                 backend: str, max_diff: int | None, sharded: bool):
        self.nbytes = nbytes
        self.key = tuple(key)
        self.pacing = pacing
        self.max_m = max_m
        self.backend = resolve_backend(backend)
        self.max_diff = max_diff
        self.sharded = sharded
        self.bytes_received = 0
        self.grow_steps = 0
        # the ENGINE owns decode dispatch (plan/execute), so the decoders
        # never self-dispatch here; their backend/max_diff are still kept
        # in sync so a decoder used directly (decoder.receive) behaves
        # like the session that owns it
        self.units = [
            UnitState(s, StreamDecoder(nbytes, local=loc, key=key,
                                       backend=self.backend,
                                       max_diff=max_diff))
            for s, loc in enumerate(locals_)]

    @property
    def n_units(self) -> int:
        return len(self.units)

    @property
    def decoded(self) -> bool:
        """True once every unit hit its ρ(0)=1 termination signal."""
        return all(u.decoder.decoded for u in self.units)

    @property
    def symbols_received(self) -> int:
        return sum(u.decoder.symbols_received for u in self.units)

    def set_backend(self, backend: str) -> None:
        self.backend = resolve_backend(backend)
        for u in self.units:
            u.decoder.backend = self.backend

    def requests(self, strict: bool = True) -> list[tuple[int, int, int]]:
        """Next window ``(shard, lo, hi)`` per still-undecoded unit.

        Window sizes come from the stateless pacing policy applied to each
        unit's own progress, clamped to ``max_m``.  A unit at ``max_m``
        without a decode signal raises ``RuntimeError`` (diverging
        reconciliation) — unless ``strict=False``, where it is skipped so
        a pipelined engine can defer the verdict until the unit's
        in-flight decode result lands.
        """
        reqs = []
        for u in self.units:
            if u.decoder.decoded:
                continue
            lo = u.decoder.symbols_received
            if lo >= self.max_m:
                if not strict:
                    continue
                what = f"shard {u.shard}" if self.sharded else \
                    "reconciliation"
                raise RuntimeError(f"{what} did not converge within "
                                   f"{self.max_m} symbols")
            reqs.append((u.shard, *self.pacing.next_window(lo, self.max_m)))
        return reqs


class DecodeUnit(NamedTuple):
    """One tick's pending work for one (peer, shard): the unit absorbed a
    window and rows ``[old, m)`` of its residual await peeling."""
    peer: PeerState
    unit: UnitState
    old: int
    m: int


# ---------------------------------------------------------------------------
# Ingest: validate + absorb (no peeling — that is the execute phase's job).
# ---------------------------------------------------------------------------
def validate_round(peer: PeerState, windows) -> list:
    """Check one round of ``(shard, symbols, start)`` windows against the
    peer's positions without mutating anything.

    Validation is all-or-nothing: every window is checked (shard id,
    order, geometry) before ANY state mutates, so a rejected round can be
    corrected and retried without losing symbols.  Overlap with already-
    consumed symbols is trimmed, wholly stale windows are dropped; a round
    may carry several windows for one unit, each validated against the
    position the previous ones will leave behind.  Returns the accepted
    ``(unit, symbols)`` list in arrival order.
    """
    have = {}
    accepted = []
    for shard_id, sym, start in windows:
        if not 0 <= shard_id < peer.n_units:
            raise ProtocolError(f"shard_id {shard_id} outside "
                                f"[0, {peer.n_units})")
        unit = peer.units[shard_id]
        pos = have.setdefault(shard_id, unit.decoder.symbols_received)
        if start > pos:
            where = f"shard {shard_id} gap" if peer.sharded else "gap"
            raise ProtocolError(f"{where}: expected window at {pos}, "
                                f"got {start}")
        if sym.nbytes != peer.nbytes:
            raise ProtocolError(f"geometry mismatch: ℓ={sym.nbytes}, "
                                f"session ℓ={peer.nbytes}")
        if start < pos:
            if start + sym.m <= pos:
                continue                      # wholly stale window
            sym = sym.window(pos - start)
        have[shard_id] = pos + sym.m
        accepted.append((unit, sym))
    return accepted


def absorb_round(peer: PeerState, windows) -> list[DecodeUnit]:
    """Validate and ingest one round of windows; return the decode units.

    Each touched unit absorbs all of its windows (local-symbol
    subtraction, chain extension of already-recovered items — see
    :meth:`repro.core.stream.StreamDecoder.absorb`) and contributes ONE
    :class:`DecodeUnit` covering everything it absorbed this round.  Units
    that terminate on absorb alone (a d=0 unit subtracts to an all-empty
    residual) are marked decoded immediately and excluded, so an identical
    peer never occupies a decode slot or stalls its neighbours.
    """
    accepted = validate_round(peer, windows)
    if not accepted:
        return []
    spans: dict[int, DecodeUnit] = {}
    for unit, sym in accepted:
        old, m = unit.decoder.absorb(sym)
        prev = spans.get(unit.shard)
        spans[unit.shard] = DecodeUnit(peer, unit,
                                       prev.old if prev else old, m)
    peer.grow_steps += 1
    out = []
    for du in spans.values():
        if du.unit.decoder.mark_decoded(at=du.m):
            continue                          # settled on absorb alone
        out.append(du)
    return out


def ingest_frames(peer: PeerState, data: bytes) -> list[DecodeUnit]:
    """Absorb one self-describing wire frame (plain, single-unit peers)."""
    sym, n_items, start = decode_frames(data)
    peer.bytes_received += len(data)
    peer.units[0].remote_items = n_items
    return absorb_round(peer, [(0, sym, start)])


def ingest_payload(peer: PeerState, data: bytes) -> list[DecodeUnit]:
    """Absorb one merged shard payload (sharded peers)."""
    n_shards, frames = decode_shard_frames(data)
    if n_shards != peer.n_units:
        raise ProtocolError(f"partition mismatch: payload has {n_shards} "
                            f"shards, session {peer.n_units}")
    peer.bytes_received += len(data)
    windows = []
    for shard_id, sym, n_items, start in frames:
        if 0 <= shard_id < peer.n_units:
            peer.units[shard_id].remote_items = n_items
        windows.append((shard_id, sym, start))
    return absorb_round(peer, windows)


# ---------------------------------------------------------------------------
# Plan: bucket pending units by shape; Execute: one dispatch per bucket.
# ---------------------------------------------------------------------------
class DecodePlan:
    """One tick's decode work, split by engine and shape.

    ``host`` units peel on the exact numpy engine; ``buckets`` maps a
    shape key — ``(mp, L, nbytes, key, max_diff)`` with ``mp`` the
    tile-padded prefix length — to the units that batch into one
    :func:`repro.kernels.ops.decode_device_batched` dispatch.  Units of
    different peers land in the same bucket whenever their shapes agree
    (the common case for peers on the same pacing schedule), which is what
    makes the engine's device cost per tick O(#buckets), not O(#peers).
    """

    def __init__(self, host: list[DecodeUnit],
                 buckets: dict[tuple, list[DecodeUnit]]):
        self.host = host
        self.buckets = buckets


def build_plan(units: list[DecodeUnit], block_m: int = 256) -> DecodePlan:
    """Split pending units into host work and per-shape device buckets."""
    host, buckets = [], {}
    for du in units:
        if du.peer.backend != "device" or du.unit.pinned_host:
            host.append(du)
            continue
        mp = ((du.m + block_m - 1) // block_m) * block_m
        D = mp if du.peer.max_diff is None else max(int(du.peer.max_diff), 1)
        key = (mp, du.unit.decoder.work.L, du.peer.nbytes, du.peer.key, D)
        buckets.setdefault(key, []).append(du)
    return DecodePlan(host, buckets)


class PendingRound:
    """In-flight device work for one tick: one pending batched decode per
    shape bucket.  ``poll()`` is non-blocking; :meth:`finish` materializes
    results, merges them into the decoders (tail-aware, so symbols
    absorbed *after* dispatch survive), applies the per-unit host fallback
    on overflow — pinning the unit to the host — and records each unit's
    termination signal at the prefix length the decode covered."""

    def __init__(self, dispatches: list):
        self._dispatches = dispatches      # [(units, PendingBatchedDecode)]
        self.n_dispatches = len(dispatches)

    def poll(self) -> bool:
        """True once every bucket's device result is ready (non-blocking)."""
        return all(pending.ready() for _, pending in self._dispatches)

    def finish(self) -> None:
        for units, pending in self._dispatches:
            for du, res in zip(units, pending.wait()):
                if res.overflow:
                    du.unit.pinned_host = True
                    du.unit.decoder.peel_window(du.old, du.m)
                else:
                    du.unit.decoder.merge_device_result(res)
                du.unit.decoder.mark_decoded(at=du.m)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def execute_round(units: list[DecodeUnit], block_m: int = 256,
                  pipeline: bool = False) -> PendingRound:
    """Decode one tick's absorbed units: host units peel immediately, each
    device bucket becomes one batched dispatch.  With ``pipeline=False``
    each bucket is decoded synchronously
    (:func:`repro.kernels.ops.decode_device_batched`) and the round is
    finished before returning; with ``pipeline=True`` each bucket is an
    async :func:`~repro.kernels.ops.decode_device_batched_start` dispatch
    and the returned :class:`PendingRound` is still in flight — the caller
    overlaps host ingest with it before calling ``finish()``.

    The unit axis is padded to the next power of two (``pad_units``): the
    unit count is a static shape in the per-bucket jit cache, so peers
    settling one by one re-use one compiled program instead of
    recompiling per departure.  A lone plain session in sync mode skips
    the batch entirely and takes :func:`~repro.kernels.ops.decode_device`
    — the PR-2 path whose Pallas peel kernels serve single-peer decodes
    on TPU."""
    from repro.kernels import ops
    plan = build_plan(units, block_m)
    for du in plan.host:
        du.unit.decoder.peel_window(du.old, du.m)
        du.unit.decoder.mark_decoded(at=du.m)
    dispatches = []
    for (mp, L, nbytes, key, D), us in plan.buckets.items():
        works = [du.unit.decoder.work for du in us]
        if pipeline:
            pending = ops.decode_device_batched_start(
                works, nbytes=nbytes, key=key, max_diff=D, block_m=block_m,
                pad_units=_next_pow2(len(us)))
        elif len(us) == 1 and not us[0].peer.sharded:
            pending = ops.PendingBatchedDecode(
                None, None, (), nbytes, results=[ops.decode_device(
                    *ops.host_symbols_to_device(works[0]), nbytes=nbytes,
                    key=key, max_diff=us[0].peer.max_diff, block_m=block_m)])
        else:
            pending = ops.PendingBatchedDecode(
                None, None, (), nbytes, results=ops.decode_device_batched(
                    works, nbytes=nbytes, key=key, max_diff=D,
                    block_m=block_m, pad_units=_next_pow2(len(us))))
        dispatches.append((us, pending))
    round_ = PendingRound(dispatches)
    if not pipeline:
        round_.finish()
    return round_


def offer_round(peer: PeerState, windows) -> bool:
    """The wrappers' push-style entry: absorb one round of in-process
    windows and decode it synchronously.  Returns ``decoded``."""
    execute_round(absorb_round(peer, windows))
    return peer.decoded


# ---------------------------------------------------------------------------
# The engine: N peers, one tick loop.
# ---------------------------------------------------------------------------
class _Registered(NamedTuple):
    stream: object      # SymbolStream | ShardedStream
    session: object     # Session | ShardedSession
    peer: PeerState
    wire: bool


class ReconcileEngine:
    """Drive any number of (stream, session) pairs through one shared
    plan/execute loop.

    Parameters
    ----------
    pipeline: overlap device decode with host ingest (double-buffering).
        While tick t's buckets peel on the device, the engine already
        fetches and absorbs tick t+1's frames — speculatively, from the
        stateless pacing policies — and only then blocks on tick t's
        results.  Peers whose decode lands keep their speculative window
        as ordinary pacing overshoot (``symbols_received`` grows,
        ``symbols_used`` does not — the termination point is pinned to the
        decoded prefix).  ``False`` reproduces the serial lockstep
        request → offer → decode trajectory of the legacy per-session
        loops exactly; :func:`~repro.protocol.session.run_session` uses
        that mode.
    block_m: device tile size — the shape-bucket quantum.

    ``ticks`` counts plan/execute rounds, ``dispatches`` the batched
    device programs issued; with N peers on one pacing schedule
    ``dispatches == ticks`` regardless of N.
    """

    def __init__(self, *, pipeline: bool = True, block_m: int = 256):
        self.pipeline = pipeline
        self.block_m = block_m
        self.ticks = 0
        self.dispatches = 0
        self._peers: list[_Registered] = []

    # -- registration -------------------------------------------------------
    def register(self, stream, session, *, wire: bool = True) -> int:
        """Attach one (stream, session) pair; returns its index.

        ``session`` is an ordinary :class:`~repro.protocol.session.Session`
        or :class:`~repro.protocol.sharded.ShardedSession` — the engine
        adopts its :class:`PeerState`, so a session driven to completion
        here reports through its own ``report()`` exactly as if it had
        been driven by its own wrapper loop.  Sharded pairs must agree on
        the partition up front (mixed shard counts would silently
        mis-reconcile in-process).
        """
        peer = session._peer
        n_shards = getattr(stream, "n_shards", None)
        if peer.sharded:
            if n_shards != peer.n_units:
                raise ProtocolError(
                    f"partition mismatch: stream has {n_shards} shards, "
                    f"session {peer.n_units}")
        elif n_shards is not None:
            raise ProtocolError("plain Session registered against a "
                                "ShardedStream; use ShardedSession")
        self._peers.append(_Registered(stream, session, peer, wire))
        return len(self._peers) - 1

    # -- ingest (request + fetch + absorb, no decode) -----------------------
    def _gather_one(self, entry: _Registered,
                    strict: bool = True) -> list[DecodeUnit]:
        reqs = entry.peer.requests(strict=strict)
        if not reqs:
            return []
        if entry.peer.sharded:
            if entry.wire:
                return ingest_payload(entry.peer, entry.stream.payload(reqs))
            windows = [(s, entry.stream.window(s, lo, hi), lo)
                       for s, lo, hi in reqs]
            return absorb_round(entry.peer, windows)
        ((_, lo, hi),) = reqs
        if entry.wire:
            return ingest_frames(entry.peer, entry.stream.frames(lo, hi))
        return absorb_round(entry.peer, [(0, entry.stream.window(lo, hi), lo)])

    def _gather(self, strict: bool = True) -> list[DecodeUnit]:
        units = []
        for entry in self._peers:
            if not entry.peer.decoded:
                units += self._gather_one(entry, strict=strict)
        return units

    # -- the loop -----------------------------------------------------------
    def tick(self) -> bool:
        """One synchronous plan/execute round over all live peers.
        Returns True while any peer still has work (event-driven callers
        loop on it; :meth:`run` adds the double-buffered fast path)."""
        units = self._gather()
        if not units:
            return any(not e.peer.decoded for e in self._peers)
        self.ticks += 1
        self.dispatches += execute_round(units, self.block_m).n_dispatches
        return any(not e.peer.decoded for e in self._peers)

    def run(self) -> list:
        """Drive every registered peer to termination; returns reports in
        registration order."""
        if not self.pipeline:
            while self.tick():
                pass
            return self.reports()
        staged = self._gather()
        while staged:
            self.ticks += 1
            round_ = execute_round(staged, self.block_m, pipeline=True)
            self.dispatches += round_.n_dispatches
            # device busy → absorb the next tick's frames now.  Speculative:
            # decodes in flight count as "not decoded", and a unit already
            # at max_m defers its non-convergence verdict.
            staged = self._gather(strict=False)
            round_.finish()
            # units that deferred (skipped by the speculative gather, still
            # undecoded after their results landed) get an authoritative
            # verdict now — this is where a genuinely diverging
            # reconciliation raises, at most one tick later than serial.
            speculated = {id(du.unit) for du in staged}
            for entry in self._peers:
                peer = entry.peer
                if peer.decoded:
                    continue
                pending = [u for u in peer.units if not u.decoder.decoded]
                unstaged = [u for u in pending
                            if id(u) not in speculated]
                for u in unstaged:
                    if u.decoder.symbols_received >= peer.max_m:
                        what = f"shard {u.shard}" if peer.sharded else \
                            "reconciliation"
                        raise RuntimeError(
                            f"{what} did not converge within "
                            f"{peer.max_m} symbols")
                if unstaged:
                    # defensive: an undecoded unit below max_m is always
                    # staged by the speculative gather today — regather
                    # authoritatively rather than exit with it stalled
                    staged += self._gather_one(entry, strict=True)
            # drop speculative units whose peer terminated meanwhile — the
            # absorbed window stays as accounted pacing overshoot.
            staged = [du for du in staged if not du.unit.decoder.decoded]
        return self.reports()

    # -- outcome ------------------------------------------------------------
    def reports(self) -> list:
        """Current reports for every registered peer, in registration
        order (valid mid-run: undecoded peers report partial recovery)."""
        return [entry.session.report() for entry in self._peers]


def serve(pairs, *, wire: bool = True, backend: str | None = None,
          pipeline: bool = True) -> list:
    """Drive ``(stream, session)`` pairs to completion on one engine.

    The multi-peer counterpart of :func:`~repro.protocol.session.run_session`:
    all sessions advance in shared ticks, decode work batches across peers
    per shape bucket, and (with ``pipeline=True``) device decode overlaps
    host ingest.  Returns the reports in input order.
    """
    engine = ReconcileEngine(pipeline=pipeline)
    for stream, session in pairs:
        if backend is not None:
            session.set_backend(backend)
        engine.register(stream, session, wire=wire)
    return engine.run()
