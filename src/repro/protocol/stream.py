"""SymbolStream — one universal coded-symbol stream, any number of peers.

The paper's central claim (§4.1) is that the coded-symbol sequence of a set
is *universal*: the same incrementally extended prefix reconciles any peer
at any difference size.  ``SymbolStream`` is that claim as an object: it
wraps exactly one :class:`~repro.core.encoder.Encoder`, owns its growing
prefix cache, and serves **zero-copy windows** (or wire-ready byte frames)
of the stream to any number of concurrent sessions.  Serving a window never
re-encodes — it extends the shared cache at most once and aliases it.
Windows are snapshots to consume immediately (a later extension reallocates
the cache and detaches them); sessions and the frame codec do exactly that.

When the underlying set changes, ``add_items`` / ``remove_items`` update
the cached prefix *in place* (linearity, §4.1) — every session keeps
pulling from the same stream.

Concurrent peers are first-class consumers: a
:class:`~repro.protocol.engine.ReconcileEngine` registers many
``(stream, session)`` pairs against the same (or different) streams and
pulls all of their windows in shared ticks — the cache still extends at
most once per tick, by whichever peer reaches deepest.
"""
from __future__ import annotations

from repro.core.encoder import Encoder
from repro.core.hashing import DEFAULT_KEY
from repro.core.symbols import CodedSymbols
from repro.core.wire import encode_frames


class SymbolStream:
    """Serve windows of one set's universal coded-symbol stream.

    Wraps one :class:`~repro.core.encoder.Encoder` (the set plus its grown
    symbol-prefix cache).  Invariants: the stream is *universal* — every
    peer sees the same symbol at the same index, whatever window schedule
    it pulls by — and serving is zero-copy: a window call extends the
    shared cache at most once and returns views of it.
    """

    def __init__(self, encoder: Encoder):
        self.encoder = encoder

    @classmethod
    def from_items(cls, items, nbytes: int, key=DEFAULT_KEY) -> "SymbolStream":
        """Stream of the set ``items`` (list of ``bytes``, ``(n, nbytes)``
        uint8 rows, or ``(n, L)`` uint32 word rows) of fixed item length
        ``nbytes``, under session ``key``."""
        enc = Encoder(nbytes, key)
        if len(items):
            enc.add_items(items)
        return cls(enc)

    # -- stream geometry ----------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self.encoder.nbytes

    @property
    def key(self):
        return self.encoder.key

    @property
    def n_items(self) -> int:
        return len(self.encoder)

    @property
    def m(self) -> int:
        """Symbols materialized so far in the shared cache."""
        return self.encoder.m

    # -- serving ------------------------------------------------------------
    def window(self, lo: int, hi: int) -> CodedSymbols:
        """Zero-copy view of stream symbols [lo, hi); extends on demand.

        Requires ``0 ≤ lo ≤ hi``; the cache grows to ``hi`` if needed.
        The view aliases the shared cache *as of this call* — consume it
        immediately (see the module docstring on view lifetime).
        """
        return self.encoder.window(lo, hi)

    def frames(self, lo: int, hi: int) -> bytes:
        """Wire frame (paper §6 encoding) for stream symbols [lo, hi).

        The frame is self-describing (:func:`repro.core.wire.encode_frames`
        with this stream's ``start=lo`` and set size), so a receiver needs
        no side channel to place it in the stream.
        """
        return encode_frames(self.window(lo, hi), start=lo,
                             n_items=self.n_items)

    # -- set mutation (updates the universal cache in place) ----------------
    def add_items(self, items) -> None:
        """Add items to the set; the cached symbol prefix is updated in
        place by linearity (§4.1), so open sessions keep pulling a
        consistent stream of the *new* set."""
        self.encoder.add_items(items)

    def remove_items(self, items) -> None:
        """Remove present items; same in-place linear update as
        :meth:`add_items`."""
        self.encoder.remove_items(items)

    # -- convenience --------------------------------------------------------
    def session(self, local=None, **kwargs):
        """A new :class:`~repro.protocol.session.Session` against this
        stream's geometry (nbytes/key inherited when ``local`` is None)."""
        from .session import Session
        if local is None:
            kwargs.setdefault("nbytes", self.nbytes)
            kwargs.setdefault("key", self.key)
        return Session(local=local, **kwargs)
