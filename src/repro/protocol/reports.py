"""Reconciliation reports — one overhead/bytes vocabulary for every peer.

:class:`SessionReport` (plain sessions) and :class:`ShardedReport` (sharded
sessions) used to duplicate the words-to-bytes and overhead arithmetic;
both now derive from :class:`ReportBase`, and the builders here assemble
either flavour from the engine's :class:`~repro.protocol.engine.PeerState`
— the single place session outcome lives, whether the peer was driven by
its own wrapper (``Session.offer``/``ShardedSession.offer_payload``) or by
a multi-peer :class:`~repro.protocol.engine.ReconcileEngine`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hashing import words_to_bytes


@dataclasses.dataclass
class ReportBase:
    """Fields and arithmetic shared by every reconciliation outcome."""
    only_remote: np.ndarray   # (r, L) uint32 words — items only in remote set
    only_local: np.ndarray    # (s, L) uint32 words — items only in local set
    nbytes: int               # item length ℓ
    symbols_used: int         # stream prefix length at the decode signal
    symbols_received: int     # including pacing overshoot
    bytes_received: int       # wire-mode traffic (0 for in-process sessions)
    remote_items: int | None  # |remote set|, learned from frame headers

    def only_remote_bytes(self) -> np.ndarray:
        """(r, ℓ) uint8 — remote-exclusive items as raw bytes."""
        return words_to_bytes(self.only_remote, self.nbytes)

    def only_local_bytes(self) -> np.ndarray:
        return words_to_bytes(self.only_local, self.nbytes)

    def overhead(self, d: int | None = None) -> float:
        """symbols_used / d (defaults to the recovered difference size)."""
        if d is None:
            d = self.only_remote.shape[0] + self.only_local.shape[0]
        return self.symbols_used / max(d, 1)


@dataclasses.dataclass
class SessionReport(ReportBase):
    """Outcome of a completed :class:`~repro.protocol.session.Session`."""


@dataclasses.dataclass
class ShardReport:
    """Per-shard slice of a completed sharded reconciliation."""
    shard: int
    only_remote: np.ndarray   # (r, L) uint32 words — remote-only, this shard
    only_local: np.ndarray    # (s, L) uint32 words — local-only, this shard
    symbols_used: int         # shard prefix length at its decode signal
    symbols_received: int     # including pacing overshoot
    remote_items: int | None  # |remote shard set|, from frame headers


@dataclasses.dataclass
class ShardedReport(ReportBase):
    """Outcome of a completed :class:`~repro.protocol.sharded.ShardedSession`.

    The aggregate fields mirror :class:`SessionReport` (the union over
    shards *is* the unsharded difference — shard invariance); ``shards``
    keeps the per-shard breakdown.
    """
    shards: list[ShardReport]  # per-shard breakdown
    grow_steps: int            # merged windows consumed (decode rounds run)


def build_session_report(peer) -> SessionReport:
    """Snapshot a single-unit peer as a :class:`SessionReport`.

    Valid at any time: before decode it reports the partial recovery
    (``symbols_used`` then falls back to ``symbols_received``); after
    decode it is the final reconciliation result.
    """
    (unit,) = peer.units
    only_remote, only_local = unit.decoder.result()
    return SessionReport(
        only_remote=only_remote, only_local=only_local,
        nbytes=peer.nbytes,
        symbols_used=unit.decoder.decoded_at or unit.decoder.symbols_received,
        symbols_received=unit.decoder.symbols_received,
        bytes_received=peer.bytes_received,
        remote_items=unit.remote_items)


def build_sharded_report(peer) -> ShardedReport:
    """Snapshot a multi-unit peer as a :class:`ShardedReport`."""
    per_shard = []
    for unit in peer.units:
        only_remote, only_local = unit.decoder.result()
        per_shard.append(ShardReport(
            shard=unit.shard, only_remote=only_remote, only_local=only_local,
            symbols_used=unit.decoder.decoded_at or
            unit.decoder.symbols_received,
            symbols_received=unit.decoder.symbols_received,
            remote_items=unit.remote_items))
    counts = [sr.remote_items for sr in per_shard]
    return ShardedReport(
        only_remote=np.concatenate([sr.only_remote for sr in per_shard]),
        only_local=np.concatenate([sr.only_local for sr in per_shard]),
        nbytes=peer.nbytes,
        symbols_used=sum(sr.symbols_used for sr in per_shard),
        symbols_received=sum(sr.symbols_received for sr in per_shard),
        bytes_received=peer.bytes_received,
        remote_items=None if any(c is None for c in counts) else sum(counts),
        shards=per_shard,
        grow_steps=peer.grow_steps)
