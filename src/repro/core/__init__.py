"""Rateless IBLT — the paper's contribution (Yang, Gilad, Alizadeh 2024)."""
from .decoder import PeelResult, peel, reconcile
from .encoder import Encoder, encode
from .hashing import (DEFAULT_KEY, bytes_to_words, siphash24, siphash24_pair,
                      words_per_item, words_to_bytes)
from .mapping import ALPHA, expected_degree, kmax, rho
from .sketch import Sketch, reconcile_sets
from .stream import StreamDecoder
from .symbols import CodedSymbols

__all__ = [
    "ALPHA", "CodedSymbols", "DEFAULT_KEY", "Encoder", "PeelResult", "Sketch",
    "StreamDecoder", "bytes_to_words", "encode", "expected_degree", "kmax",
    "peel", "reconcile", "reconcile_sets", "rho", "siphash24",
    "siphash24_pair", "words_per_item", "words_to_bytes",
]
