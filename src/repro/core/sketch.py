"""Legacy one-call wrappers over the session protocol.

The primary entry point is :mod:`repro.protocol` — ``SymbolStream`` /
``Session`` / ``run_session`` (and their sharded counterparts) — which is
what ``reconcile_sets`` delegates to::

    from repro.core import Sketch, reconcile_sets
    a = Sketch.from_items(list_of_bytes_a, nbytes=32)
    b = Sketch.from_items(list_of_bytes_b, nbytes=32)
    only_a, only_b, m_used = reconcile_sets(a, b)   # one Session, hidden

``reconcile_sets`` is kept for the common two-sets-in-one-process case and
for API compatibility; it offers no pacing control, no wire bytes, no
backend selection and no multi-peer reuse of the stream.  New code should
open a ``Session`` against a ``SymbolStream`` directly (see
``examples/quickstart.py`` and ``docs/ARCHITECTURE.md``).
"""
from __future__ import annotations

from .decoder import PeelResult, peel
from .encoder import Encoder
from .hashing import DEFAULT_KEY
from .symbols import CodedSymbols


class Sketch(Encoder):
    """An Encoder with convenience constructors/decoders."""

    @classmethod
    def from_items(cls, items, nbytes: int, key=DEFAULT_KEY) -> "Sketch":
        s = cls(nbytes, key)
        if len(items):
            s.add_items(items)
        return s

    def decode_against(self, remote: CodedSymbols) -> PeelResult:
        """Peel remote_prefix ⊖ local_prefix (same m)."""
        return peel(remote.subtract(self.symbols(remote.m)), self.key)


def reconcile_sets(a: Sketch, b: Sketch, block: int = 8, max_m: int = 1 << 22):
    """Run the rateless protocol: A streams windows until B decodes.

    Thin wrapper over ``repro.protocol`` (one `Session` pulling A's
    `SymbolStream` with the doubling schedule this function always used).
    Returns (items_only_in_A bytes-array, items_only_in_B, symbols_used).
    """
    from repro.protocol import Exponential, Session, SymbolStream, run_session
    session = Session(local=b, pacing=Exponential(block=block, growth=2.0),
                      max_m=max_m)
    rep = run_session(SymbolStream(a), session)
    return rep.only_remote_bytes(), rep.only_local_bytes(), rep.symbols_used
