"""High-level Rateless IBLT API.

    >>> from repro.core import Sketch, reconcile_sets
    >>> a = Sketch.from_items(list_of_bytes_a, nbytes=32)
    >>> b = Sketch.from_items(list_of_bytes_b, nbytes=32)
    >>> only_a, only_b, m_used = reconcile_sets(a, b)

`reconcile_sets` mimics the live protocol: stream A's symbols in growing
blocks into a StreamDecoder holding B, stop at decode (symbol 0 empties).
"""
from __future__ import annotations

from .decoder import PeelResult, peel
from .encoder import Encoder
from .hashing import DEFAULT_KEY, words_to_bytes
from .stream import StreamDecoder
from .symbols import CodedSymbols


class Sketch(Encoder):
    """An Encoder with convenience constructors/decoders."""

    @classmethod
    def from_items(cls, items, nbytes: int, key=DEFAULT_KEY) -> "Sketch":
        s = cls(nbytes, key)
        if len(items):
            s.add_items(items)
        return s

    def decode_against(self, remote: CodedSymbols) -> PeelResult:
        """Peel remote_prefix ⊖ local_prefix (same m)."""
        return peel(remote.subtract(self.symbols(remote.m)), self.key)


def reconcile_sets(a: Sketch, b: Sketch, block: int = 8, max_m: int = 1 << 22):
    """Run the rateless protocol: A streams blocks until B decodes.

    Returns (items_only_in_A bytes-array, items_only_in_B, symbols_used).
    """
    dec = StreamDecoder(b.nbytes, local=b, key=b.key)
    m = 0
    while m < max_m:
        take = max(block, m)  # exponential-ish growth of block size
        sym = a.symbols(m + take)
        batch = CodedSymbols(sym.sums[m:], sym.checks[m:], sym.counts[m:],
                             a.nbytes)
        m += take
        if dec.receive(batch):
            only_a, only_b = dec.result()
            return (words_to_bytes(only_a, a.nbytes),
                    words_to_bytes(only_b, a.nbytes), dec.decoded_at)
    raise RuntimeError("reconciliation did not converge within max_m symbols")
