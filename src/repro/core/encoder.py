"""Rateless IBLT encoder — host path (paper §4.2, §6).

The Go reference implementation extends the stream one symbol at a time with
a priority queue.  On this framework's host path we keep the *incremental*
semantics (a `Encoder` owns a growing prefix cache and extends it on demand,
so a node can stream an ever-longer prefix to any number of peers) but
replace the heap with vectorized chain-advancing rounds: each round advances
every item whose next mapped index falls inside the requested window and
XOR-accumulates with a sort + ``bitwise_xor.reduceat`` — O(total mapped
indices) work, the same asymptotics as the heap, at numpy speed.

Linearity makes the cache updatable in place: ``add_items`` /
``remove_items`` XOR the delta-set's symbols into the prefix (paper §4.1's
"treat the updates A △ A′ as a set and subtract its coded symbols").
"""
from __future__ import annotations

import numpy as np

from .hashing import DEFAULT_KEY, bytes_to_words, siphash24, words_per_item
from .mapping import _jump_np, map_seeds
from .symbols import CodedSymbols


def _xor_accumulate(sums: np.ndarray, checks: np.ndarray, counts: np.ndarray,
                    idx: np.ndarray, items: np.ndarray, hashes: np.ndarray,
                    sides: np.ndarray, base: int = 0) -> None:
    """Scatter-XOR ``items``/``hashes`` into rows ``idx - base`` (repeats ok)."""
    if idx.size == 0:
        return
    order = np.argsort(idx, kind="stable")
    sidx = idx[order] - base
    starts = np.flatnonzero(np.r_[True, sidx[1:] != sidx[:-1]])
    rows = sidx[starts]
    sums[rows] ^= np.bitwise_xor.reduceat(items[order], starts, axis=0)
    checks[rows] ^= np.bitwise_xor.reduceat(hashes[order], starts)
    np.add.at(counts, sidx, sides[order])


class Encoder:
    """Incremental rateless encoder for one set.

    Parameters
    ----------
    nbytes: item length ℓ in bytes (all items fixed-length).
    key: 128-bit session key (checksum PRF + mapping PRNG are derived).
    """

    def __init__(self, nbytes: int, key=DEFAULT_KEY):
        self.nbytes = nbytes
        self.L = words_per_item(nbytes)
        self.key = key
        self._items = np.zeros((0, self.L), np.uint32)
        self._hashes = np.zeros(0, np.uint64)
        self._seeds = np.zeros(0, np.uint64)
        self._next = np.zeros(0, np.int64)    # next unencoded mapped index
        self._state = np.zeros(0, np.uint64)  # PRNG state at `_next`
        self._weight = np.zeros(0, np.int8)   # +1 present, 0 tombstone
        self._cache = CodedSymbols.zeros(0, nbytes)

    # -- set mutation -------------------------------------------------------
    def __len__(self) -> int:
        return int((self._weight == 1).sum())

    @property
    def m(self) -> int:
        return self._cache.m

    def _coerce(self, items) -> np.ndarray:
        if isinstance(items, np.ndarray) and items.dtype == np.uint32:
            assert items.shape[1] == self.L
            return items
        return bytes_to_words(items, self.nbytes)

    def add_items(self, items) -> None:
        words = self._coerce(items)
        n = words.shape[0]
        hashes = siphash24(words, self.key, self.nbytes)
        seeds = map_seeds(words, self.key, self.nbytes)
        nxt = np.zeros(n, np.int64)
        state = seeds.copy()
        if self.m > 0:  # retro-encode the new items into the existing prefix
            nxt, state = self._encode_range(words, hashes, nxt, state,
                                            np.ones(n, np.int8), 0, self.m)
        self._items = np.concatenate([self._items, words])
        self._hashes = np.concatenate([self._hashes, hashes])
        self._seeds = np.concatenate([self._seeds, seeds])
        self._next = np.concatenate([self._next, nxt])
        self._state = np.concatenate([self._state, state])
        self._weight = np.concatenate([self._weight, np.ones(n, np.int8)])

    def remove_items(self, items) -> None:
        """Remove items (must be present).  XORs them out of the cached
        prefix and tombstones them for future extensions."""
        words = self._coerce(items)
        hashes = siphash24(words, self.key, self.nbytes)
        seeds = map_seeds(words, self.key, self.nbytes)
        if self.m > 0:
            self._encode_range(words, hashes, np.zeros(len(words), np.int64),
                               seeds.copy(), -np.ones(len(words), np.int8),
                               0, self.m)
        # tombstone by matching hash (hash collision on removal is negligible)
        kill = np.isin(self._hashes, hashes) & (self._weight == 1)
        self._weight[kill] = 0

    # -- encoding -----------------------------------------------------------
    def _encode_range(self, items, hashes, nxt, state, sides, lo: int, hi: int):
        """XOR chains of `items` into cache rows [lo, hi).  Returns final
        (next, state) positioned at the first index >= hi."""
        sums = self._cache.sums
        checks = self._cache.checks
        counts = self._cache.counts
        while True:
            live = np.flatnonzero(nxt < hi)
            if live.size == 0:
                return nxt, state
            _xor_accumulate(sums, checks, counts, nxt[live], items[live],
                            hashes[live], sides[live].astype(np.int64))
            nn, ns = _jump_np(nxt[live], state[live])
            nxt[live] = nn
            state[live] = ns

    def extend(self, m: int) -> None:
        """Grow the cached prefix to m coded symbols."""
        if m <= self.m:
            return
        old = self.m
        grown = CodedSymbols.zeros(m, self.nbytes)
        grown.sums[:old] = self._cache.sums
        grown.checks[:old] = self._cache.checks
        grown.counts[:old] = self._cache.counts
        self._cache = grown
        live = self._weight == 1
        nxt, state = self._encode_range(
            self._items[live], self._hashes[live], self._next[live],
            self._state[live], self._weight[live], old, m)
        self._next[live] = nxt
        self._state[live] = state

    def symbols(self, m: int) -> CodedSymbols:
        """The first m coded symbols (prefix of the universal sequence)."""
        self.extend(m)
        return self._cache.prefix(m).copy()

    def window(self, lo: int, hi: int) -> CodedSymbols:
        """Zero-copy view of coded symbols [lo, hi), extending on demand.

        The view aliases the cache *as of this call*: a later ``extend``
        past the current prefix reallocates the cache and detaches the
        view, while in-prefix ``add_items``/``remove_items`` mutate it.
        Consume (or ``.copy()``) a window before touching the encoder
        again; do not hold views across encoder operations.
        """
        self.extend(hi)
        return self._cache.window(lo, hi)


def encode(items, nbytes: int, m: int, key=DEFAULT_KEY) -> CodedSymbols:
    """One-shot: first m coded symbols of a set."""
    enc = Encoder(nbytes, key)
    enc.add_items(items)
    return enc.symbols(m)
