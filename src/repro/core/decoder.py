"""Peeling decoder (paper §3) — vectorized host path + device dispatch.

A coded symbol is *pure* when its checksum equals the keyed hash of its sum;
its sum is then a source symbol.  We peel in vectorized waves: find every
pure symbol, dedupe recovered items by checksum, XOR each item out of its
whole mapped-index chain, repeat.  Success ⇔ all symbols end empty — and by
the ρ(0)=1 property symbol 0 empties last, which is the stream-termination
signal used by the incremental decoder.

``backend`` selects the peel engine: ``"host"`` (numpy, this module),
``"device"`` (the :mod:`repro.kernels.peel` wave decoder — one jit program
on TPU, pure-jnp engine on CPU), or ``"auto"`` (device iff a TPU backend is
present).  Both engines recover the identical difference; a device decode
that overflows its fixed ``max_diff`` buffers falls back to the host path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .encoder import _xor_accumulate
from .hashing import DEFAULT_KEY, siphash24
from .mapping import map_seeds, walk_chains
from .symbols import CodedSymbols

BACKENDS = ("host", "device", "auto")


def resolve_backend(backend: str) -> str:
    """Map "auto" to "device" on TPU hosts, "host" elsewhere."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend != "auto":
        return backend
    try:
        import jax
        return "device" if jax.default_backend() == "tpu" else "host"
    except Exception:
        return "host"


@dataclasses.dataclass
class PeelResult:
    items: np.ndarray    # (r, L) uint32 recovered source symbols
    sides: np.ndarray    # (r,) int8 — +1 exclusive to A, −1 exclusive to B
    success: bool        # all source symbols recovered (symbols all empty)
    rounds: int


def peel(sym: CodedSymbols, key=DEFAULT_KEY, max_rounds: int = 10_000,
         backend: str = "host", max_diff: int | None = None) -> PeelResult:
    if resolve_backend(backend) == "device":
        res = _peel_device(sym, key, max_rounds, max_diff)
        if res is not None:
            return res
        # max_diff overflow — redecode exactly on the host
    return _peel_host(sym, key, max_rounds)


def _peel_device(sym, key, max_rounds, max_diff) -> PeelResult | None:
    """Device wave decode; None when the max_diff bound overflowed."""
    from repro.kernels.ops import decode_device, host_symbols_to_device
    res = decode_device(*host_symbols_to_device(sym), nbytes=sym.nbytes,
                        key=key, max_diff=max_diff, max_rounds=max_rounds)
    if res.overflow:
        return None
    return PeelResult(res.items, res.sides, res.success, res.rounds)


def _peel_host(sym: CodedSymbols, key, max_rounds: int) -> PeelResult:
    sym = sym.copy()
    m = sym.m
    rec_items = []
    rec_sides = []
    rec_hashes = np.zeros(0, np.uint64)
    rounds = 0
    # candidate indices to re-test for purity (all, initially)
    cand = np.arange(m, dtype=np.int64)
    while rounds < max_rounds and cand.size:
        rounds += 1
        h = siphash24(sym.sums[cand], key, sym.nbytes)
        pure = cand[(h == sym.checks[cand]) & (sym.counts[cand] != 0)]
        if pure.size == 0:
            break
        items = sym.sums[pure]
        hashes = sym.checks[pure]
        sides = np.sign(sym.counts[pure]).astype(np.int8)
        # dedupe: one item may be pure at several indices simultaneously,
        # and must not re-enter once recovered in an earlier wave
        _, first = np.unique(hashes, return_index=True)
        items, hashes, sides = items[first], hashes[first], sides[first]
        fresh = ~np.isin(hashes, rec_hashes)
        items, hashes, sides = items[fresh], hashes[fresh], sides[fresh]
        if items.shape[0] == 0:
            break
        rec_hashes = np.concatenate([rec_hashes, hashes])
        rec_items.append(items)
        rec_sides.append(sides)
        # XOR every recovered item out of its whole chain
        seeds = map_seeds(items, key, sym.nbytes)
        touched = _remove_chains(sym, items, hashes, sides, seeds, key)
        cand = np.unique(touched)
    items = np.concatenate(rec_items) if rec_items else np.zeros((0, sym.L), np.uint32)
    sides = np.concatenate(rec_sides) if rec_sides else np.zeros(0, np.int8)
    success = bool(sym.is_empty().all())
    return PeelResult(items, sides, success, rounds)


def _remove_chains(sym: CodedSymbols, items, hashes, sides, seeds, key):
    """XOR items out of all their mapped indices < m.  Returns touched rows."""
    nxt = np.zeros(items.shape[0], np.int64)
    state = seeds.astype(np.uint64).copy()

    def remove(live, idx):
        _xor_accumulate(sym.sums, sym.checks, sym.counts, idx, items[live],
                        hashes[live], -sides[live].astype(np.int64))

    return walk_chains(nxt, state, sym.m, remove)


def reconcile(sym_a: CodedSymbols, sym_b: CodedSymbols, key=DEFAULT_KEY,
              backend: str = "host",
              max_diff: int | None = None) -> PeelResult:
    """Decode A △ B from equal-length symbol prefixes of A and B."""
    return peel(sym_a.subtract(sym_b), key, backend=backend,
                max_diff=max_diff)
