"""Peeling decoder (paper §3) — vectorized host path + device path.

A coded symbol is *pure* when its checksum equals the keyed hash of its sum;
its sum is then a source symbol.  We peel in vectorized waves: find every
pure symbol, dedupe recovered items by checksum, XOR each item out of its
whole mapped-index chain, repeat.  Success ⇔ all symbols end empty — and by
the ρ(0)=1 property symbol 0 empties last, which is the stream-termination
signal used by the incremental decoder.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .encoder import _xor_accumulate
from .hashing import DEFAULT_KEY, siphash24
from .mapping import _jump_np, map_seeds
from .symbols import CodedSymbols


@dataclasses.dataclass
class PeelResult:
    items: np.ndarray    # (r, L) uint32 recovered source symbols
    sides: np.ndarray    # (r,) int8 — +1 exclusive to A, −1 exclusive to B
    success: bool        # all source symbols recovered (symbols all empty)
    rounds: int


def peel(sym: CodedSymbols, key=DEFAULT_KEY, max_rounds: int = 10_000) -> PeelResult:
    sym = sym.copy()
    m = sym.m
    rec_items = []
    rec_sides = []
    seen = set()
    rounds = 0
    # candidate indices to re-test for purity (all, initially)
    cand = np.arange(m, dtype=np.int64)
    while rounds < max_rounds and cand.size:
        rounds += 1
        h = siphash24(sym.sums[cand], key, sym.nbytes)
        pure = cand[(h == sym.checks[cand]) & (sym.counts[cand] != 0)]
        if pure.size == 0:
            break
        items = sym.sums[pure]
        hashes = sym.checks[pure]
        sides = np.sign(sym.counts[pure]).astype(np.int8)
        # dedupe: one item may be pure at several indices simultaneously
        _, first = np.unique(hashes, return_index=True)
        items, hashes, sides = items[first], hashes[first], sides[first]
        ok = np.array([h not in seen for h in hashes.tolist()])
        items, hashes, sides = items[ok], hashes[ok], sides[ok]
        if items.shape[0] == 0:
            break
        seen.update(hashes.tolist())
        rec_items.append(items)
        rec_sides.append(sides)
        # XOR every recovered item out of its whole chain
        seeds = map_seeds(items, key, sym.nbytes)
        touched = _remove_chains(sym, items, hashes, sides, seeds, key)
        cand = np.unique(touched)
    items = np.concatenate(rec_items) if rec_items else np.zeros((0, sym.L), np.uint32)
    sides = np.concatenate(rec_sides) if rec_sides else np.zeros(0, np.int8)
    success = bool(sym.is_empty().all())
    return PeelResult(items, sides, success, rounds)


def _remove_chains(sym: CodedSymbols, items, hashes, sides, seeds, key):
    """XOR items out of all their mapped indices < m.  Returns touched rows."""
    m = sym.m
    n = items.shape[0]
    nxt = np.zeros(n, np.int64)
    state = seeds.astype(np.uint64).copy()
    touched = []
    while True:
        live = np.flatnonzero(nxt < m)
        if live.size == 0:
            break
        idx = nxt[live]
        touched.append(idx.copy())
        _xor_accumulate(sym.sums, sym.checks, sym.counts, idx, items[live],
                        hashes[live], -sides[live].astype(np.int64))
        nn, ns = _jump_np(idx, state[live])
        nxt[live] = nn
        state[live] = ns
    return np.concatenate(touched) if touched else np.zeros(0, np.int64)


def reconcile(sym_a: CodedSymbols, sym_b: CodedSymbols, key=DEFAULT_KEY) -> PeelResult:
    """Decode A △ B from equal-length symbol prefixes of A and B."""
    return peel(sym_a.subtract(sym_b), key)
