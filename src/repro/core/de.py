"""Density evolution for Rateless IBLT (paper §5, Theorem 5.1).

Decoding succeeds w.h.p. iff  exp((1/α)·Ei(−q/(αη))) < q  for all q ∈ (0,1].
η*(α) is the smallest feasible η — the asymptotic communication overhead
(η*(0.5) ≈ 1.35, Corollary 5.2).  Self-contained Ei implementation (no scipy
in this container).
"""
from __future__ import annotations

import math

import numpy as np

_EULER = 0.5772156649015328606


def e1(y: float) -> float:
    """Exponential integral E1(y), y > 0.  Ei(−y) = −E1(y)."""
    if y <= 0:
        raise ValueError("E1 domain is y > 0")
    if y <= 1.0:
        # series: E1 = −γ − ln y + Σ (−1)^{k+1} y^k / (k·k!)
        s = 0.0
        term = 1.0
        for k in range(1, 40):
            term *= -y / k
            s -= term / k
        return -_EULER - math.log(y) + s
    # continued fraction (Lentz): E1 = e^{-y} · 1/(y+1−1/(y+3−4/(y+5−…)))
    b = y + 1.0
    c = 1e308
    d = 1.0 / b
    h = d
    for k in range(1, 200):
        a = -k * k
        b += 2.0
        d = 1.0 / (a * d + b)
        c = b + a / c
        dl = c * d
        h *= dl
        if abs(dl - 1.0) < 1e-15:
            break
    return h * math.exp(-y)


def ei_neg(y: float) -> float:
    """Ei(−y) for y > 0."""
    return -e1(y)


def f_limit(q: np.ndarray, eta: float, alpha: float = 0.5) -> np.ndarray:
    """lim_{n→∞} f(q) = exp((1/α)·Ei(−q/(αη)))  (Theorem 5.1)."""
    q = np.asarray(q, dtype=np.float64)
    vals = np.array([math.exp(ei_neg(max(x, 1e-300) / (alpha * eta)) / alpha)
                     for x in q.ravel()])
    return vals.reshape(q.shape)


def feasible(eta: float, alpha: float = 0.5, grid: int = 4000) -> bool:
    """Check Eq. 2:  f_limit(q) < q for all q ∈ (0, 1]."""
    q = np.concatenate([np.logspace(-8, 0, grid // 2),
                        np.linspace(1e-4, 1.0, grid // 2)])
    return bool(np.all(f_limit(q, eta, alpha) < q))


def eta_star(alpha: float = 0.5, tol: float = 1e-4) -> float:
    """Smallest feasible η — the asymptotic overhead for this α."""
    lo, hi = 0.5, 8.0
    assert feasible(hi, alpha)
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if feasible(mid, alpha):
            hi = mid
        else:
            lo = mid
    return hi


def recovered_fraction(eta: float, alpha: float = 0.5, iters: int = 10_000):
    """Fixed point of q ← f(q): expected unrecovered fraction (Fig. 5)."""
    q = 1.0
    for _ in range(iters):
        nq = float(f_limit(np.array([q]), eta, alpha)[0])
        if abs(nq - q) < 1e-12:
            break
        q = nq
    return 1.0 - q
