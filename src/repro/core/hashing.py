"""Keyed 64-bit hashing for Rateless IBLT (paper §4.3).

The paper uses SipHash, a keyed short-input PRF, for the per-symbol
``checksum`` field and (here) to seed the deterministic mapping PRNG.  We
implement SipHash-2-4 twice:

* host path — vectorized numpy over ``uint64`` (CPUs have native u64);
* device path — JAX over ``(hi, lo)`` ``uint32`` lane pairs, because TPUs
  have no 64-bit integer lanes.  Bit-exact with the host path (tested).

Items are fixed-length bit strings stored as little-endian ``uint32`` word
arrays of shape ``(..., L)``; the true byte length feeds SipHash's length
block so different-ℓ reconciliations never alias.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Keys.  A reconciliation session is parameterized by a 128-bit key (paper
# §4.3: secret, coordinated out of band when adversarial workloads matter).
# The checksum PRF and the mapping PRNG must be independent, so we tweak the
# user key with distinct constants for each role.
# ---------------------------------------------------------------------------
DEFAULT_KEY = (0x0706050403020100, 0x0F0E0D0C0B0A0908)
_MAP_TWEAK = (0x9E3779B97F4A7C15, 0xD1B54A32D192ED03)

_U64 = np.uint64


def map_key(key=DEFAULT_KEY):
    """Derive the mapping-PRNG key from the session key."""
    return (key[0] ^ _MAP_TWEAK[0], key[1] ^ _MAP_TWEAK[1])


# ---------------------------------------------------------------------------
# Host path: numpy uint64, vectorized over leading axes.
# ---------------------------------------------------------------------------
def _rotl_np(x, r):
    r = _U64(r)
    return (x << r) | (x >> _U64(64 - int(r)))


def _sipround_np(v0, v1, v2, v3):
    v0 = v0 + v1
    v1 = _rotl_np(v1, 13)
    v1 ^= v0
    v0 = _rotl_np(v0, 32)
    v2 = v2 + v3
    v3 = _rotl_np(v3, 16)
    v3 ^= v2
    v0 = v0 + v3
    v3 = _rotl_np(v3, 21)
    v3 ^= v0
    v2 = v2 + v1
    v1 = _rotl_np(v1, 17)
    v1 ^= v2
    v2 = _rotl_np(v2, 32)
    return v0, v1, v2, v3


def siphash24(words: np.ndarray, key=DEFAULT_KEY, nbytes: int | None = None) -> np.ndarray:
    """SipHash-2-4 of uint32 word arrays ``(..., L)`` -> uint64 ``(...,)``.

    Message = the L little-endian 32-bit words; the final block carries
    ``nbytes & 0xff`` in the top byte per the SipHash spec.
    """
    words = np.asarray(words, dtype=np.uint32)
    if words.ndim == 1:
        words = words[None, :]
        squeeze = True
    else:
        squeeze = False
    lead = words.shape[:-1]
    L = words.shape[-1]
    if nbytes is None:
        nbytes = 4 * L

    k0 = _U64(key[0])
    k1 = _U64(key[1])
    v0 = np.full(lead, k0 ^ _U64(0x736F6D6570736575), dtype=np.uint64)
    v1 = np.full(lead, k1 ^ _U64(0x646F72616E646F6D), dtype=np.uint64)
    v2 = np.full(lead, k0 ^ _U64(0x6C7967656E657261), dtype=np.uint64)
    v3 = np.full(lead, k1 ^ _U64(0x7465646279746573), dtype=np.uint64)

    w64 = words.astype(np.uint64)
    full = L // 2
    for i in range(full):
        m = w64[..., 2 * i] | (w64[..., 2 * i + 1] << _U64(32))
        v3 ^= m
        v0, v1, v2, v3 = _sipround_np(v0, v1, v2, v3)
        v0, v1, v2, v3 = _sipround_np(v0, v1, v2, v3)
        v0 ^= m
    # final block: leftover word (if L odd) + length byte in the top byte.
    b = _U64((nbytes & 0xFF)) << _U64(56)
    if L % 2 == 1:
        b = b | w64[..., L - 1]
    v3 ^= b
    v0, v1, v2, v3 = _sipround_np(v0, v1, v2, v3)
    v0, v1, v2, v3 = _sipround_np(v0, v1, v2, v3)
    v0 ^= b
    v2 ^= _U64(0xFF)
    for _ in range(4):
        v0, v1, v2, v3 = _sipround_np(v0, v1, v2, v3)
    out = v0 ^ v1 ^ v2 ^ v3
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Device path: JAX (hi, lo) uint32 pairs.  TPU-native u64 emulation.
# ---------------------------------------------------------------------------
def _add64(ah, al, bh, bl):
    lo = al + bl
    carry = (lo < al).astype(jnp.uint32)
    hi = ah + bh + carry
    return hi, lo


def _rotl64(h, l, r):
    if r == 32:
        return l, h
    if r > 32:
        h, l = l, h
        r -= 32
    rr = jnp.uint32(r)
    ri = jnp.uint32(32 - r)
    nh = (h << rr) | (l >> ri)
    nl = (l << rr) | (h >> ri)
    return nh, nl


def _sipround_j(v):
    (v0h, v0l), (v1h, v1l), (v2h, v2l), (v3h, v3l) = v
    v0h, v0l = _add64(v0h, v0l, v1h, v1l)
    v1h, v1l = _rotl64(v1h, v1l, 13)
    v1h, v1l = v1h ^ v0h, v1l ^ v0l
    v0h, v0l = _rotl64(v0h, v0l, 32)
    v2h, v2l = _add64(v2h, v2l, v3h, v3l)
    v3h, v3l = _rotl64(v3h, v3l, 16)
    v3h, v3l = v3h ^ v2h, v3l ^ v2l
    v0h, v0l = _add64(v0h, v0l, v3h, v3l)
    v3h, v3l = _rotl64(v3h, v3l, 21)
    v3h, v3l = v3h ^ v0h, v3l ^ v0l
    v2h, v2l = _add64(v2h, v2l, v1h, v1l)
    v1h, v1l = _rotl64(v1h, v1l, 17)
    v1h, v1l = v1h ^ v2h, v1l ^ v2l
    v2h, v2l = _rotl64(v2h, v2l, 32)
    return (v0h, v0l), (v1h, v1l), (v2h, v2l), (v3h, v3l)


def _const_pair(x):
    return (jnp.uint32((x >> 32) & 0xFFFFFFFF), jnp.uint32(x & 0xFFFFFFFF))


def siphash24_pair(words, key=DEFAULT_KEY, nbytes: int | None = None):
    """JAX SipHash-2-4 of uint32 words ``(..., L)`` -> (hi, lo) uint32 pair.

    Bit-exact with :func:`siphash24` (hi = result >> 32, lo = low word).
    Works inside jit / vmap / Pallas (elementwise + shifts only).
    """
    words = jnp.asarray(words, dtype=jnp.uint32)
    L = words.shape[-1]
    if nbytes is None:
        nbytes = 4 * L
    lead = words.shape[:-1]

    def bcast(pair):
        return (jnp.broadcast_to(pair[0], lead), jnp.broadcast_to(pair[1], lead))

    k0h, k0l = _const_pair(key[0])
    k1h, k1l = _const_pair(key[1])
    c0, c1, c2, c3 = (_const_pair(x) for x in (
        0x736F6D6570736575, 0x646F72616E646F6D, 0x6C7967656E657261, 0x7465646279746573))
    v = [bcast((k0h ^ c0[0], k0l ^ c0[1])), bcast((k1h ^ c1[0], k1l ^ c1[1])),
         bcast((k0h ^ c2[0], k0l ^ c2[1])), bcast((k1h ^ c3[0], k1l ^ c3[1]))]

    full = L // 2
    for i in range(full):
        mh, ml = words[..., 2 * i + 1], words[..., 2 * i]
        v[3] = (v[3][0] ^ mh, v[3][1] ^ ml)
        v = list(_sipround_j(tuple(v)))
        v = list(_sipround_j(tuple(v)))
        v[0] = (v[0][0] ^ mh, v[0][1] ^ ml)
    bh = jnp.uint32((nbytes & 0xFF) << 24)
    bl = jnp.uint32(0)
    if L % 2 == 1:
        bl = words[..., L - 1]
    bh = jnp.broadcast_to(bh, lead)
    bl = jnp.broadcast_to(bl, lead)
    v[3] = (v[3][0] ^ bh, v[3][1] ^ bl)
    v = list(_sipround_j(tuple(v)))
    v = list(_sipround_j(tuple(v)))
    v[0] = (v[0][0] ^ bh, v[0][1] ^ bl)
    ffh, ffl = jnp.uint32(0), jnp.uint32(0xFF)
    v[2] = (v[2][0] ^ ffh, v[2][1] ^ ffl)
    for _ in range(4):
        v = list(_sipround_j(tuple(v)))
    hi = v[0][0] ^ v[1][0] ^ v[2][0] ^ v[3][0]
    lo = v[0][1] ^ v[1][1] ^ v[2][1] ^ v[3][1]
    return hi, lo


# ---------------------------------------------------------------------------
# Byte <-> word helpers.
# ---------------------------------------------------------------------------
def words_per_item(nbytes: int) -> int:
    return (nbytes + 3) // 4


def bytes_to_words(items, nbytes: int) -> np.ndarray:
    """(n, nbytes) uint8 (or list[bytes]) -> (n, L) uint32 little-endian."""
    if isinstance(items, (list, tuple)):
        items = np.frombuffer(b"".join(items), dtype=np.uint8).reshape(len(items), nbytes)
    items = np.asarray(items, dtype=np.uint8)
    n = items.shape[0]
    L = words_per_item(nbytes)
    pad = 4 * L - nbytes
    if pad:
        items = np.concatenate([items, np.zeros((n, pad), dtype=np.uint8)], axis=1)
    return items.reshape(n, L, 4).view(np.uint32).reshape(n, L).copy()


def words_to_bytes(words: np.ndarray, nbytes: int) -> np.ndarray:
    words = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    n = words.shape[0]
    if n == 0:
        return np.zeros((0, nbytes), dtype=np.uint8)
    raw = words.view(np.uint8).reshape(n, -1)
    return raw[:, :nbytes].copy()
