"""The Rateless IBLT mapping (paper §4.1–4.2).

A source symbol is mapped to coded-symbol index ``i`` with probability
``ρ(i) = 1/(1 + αi)``, α = 0.5.  Every symbol maps to index 0 (ρ(0)=1).
Subsequent mapped indices are produced by *skip sampling*: from index ``i``
jump ``g = max(1, ⌈C⁻¹(r)⌉)`` with ``C⁻¹(r) ≈ (1.5+i)·((1−r)^{−1/2} − 1)``
and ``r ∈ [0,1)`` drawn from an xorshift64 PRNG seeded by the symbol's keyed
hash.  Constant cost per mapped index, O(log m) mapped indices in the first
``m`` — the property that gives Rateless IBLT its O(ℓ·log d) costs.

Determinism contract: the host (numpy) and device (JAX uint32-pair) chains
produce *identical* index sequences.  All real arithmetic is float32 with an
identical op sequence on both paths (no FMA-fusable patterns), so IEEE-754
guarantees bit-equal results.
"""
from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

from .hashing import map_key, siphash24, siphash24_pair

ALPHA = 0.5

_U64 = np.uint64


def rho(i):
    """Mapping probability ρ(i) = 1/(1 + αi)."""
    return 1.0 / (1.0 + ALPHA * np.asarray(i, dtype=np.float64))


def expected_degree(m: int) -> float:
    """E[#mapped indices among the first m] = Σ_{i<m} ρ(i)."""
    i = np.arange(m, dtype=np.float64)
    return float(np.sum(1.0 / (1.0 + ALPHA * i)))


def kmax(m: int) -> int:
    """Static bound on mapped-index count within the first m coded symbols.

    The count is a sum of independent Bernoulli(ρ(i)) with mean μ ≈ 2·ln m;
    a Bernstein tail at μ + 8√μ + 10 is ≪ 1e-12.  Used by the fixed-shape
    device encoder; the host encoder walks exact chains and never truncates.
    """
    mu = 2.0 * math.log(m + 2.0)
    return int(math.ceil(mu + 8.0 * math.sqrt(mu) + 10.0))


# ---------------------------------------------------------------------------
# PRNG: xorshift64 seeded with the keyed 64-bit item hash (forced nonzero).
# ---------------------------------------------------------------------------
def _xs64_np(s: np.ndarray) -> np.ndarray:
    s = s ^ (s << _U64(13))
    s = s ^ (s >> _U64(7))
    s = s ^ (s << _U64(17))
    return s


def map_seeds(words: np.ndarray, key, nbytes: int | None = None) -> np.ndarray:
    """Per-item mapping-PRNG seed (uint64, nonzero) from the session key."""
    s = siphash24(words, map_key(key), nbytes)
    return s | _U64(1)


def _jump_np(idx: np.ndarray, state: np.ndarray):
    """One skip-sampling step (vectorized).  idx int64, state uint64."""
    state = _xs64_np(state)
    rbits = (state >> _U64(40)).astype(np.float32)        # top 24 bits
    r = rbits * np.float32(2.0 ** -24)                    # uniform [0,1)
    t = np.float32(1.0) / np.sqrt(np.float32(1.0) - r)    # (1-r)^(-1/2)
    u = t - np.float32(1.0)
    f = np.float32(1.5) + idx.astype(np.float32)
    g = np.ceil(f * u).astype(np.int64)
    g = np.maximum(g, 1)
    return idx + g, state


def advance_np(idx, state, limit):
    """Advance chains until every idx >= limit.  Yields (active_sel, idx)
    batches for the encoder.  idx/state are modified in place."""
    while True:
        active = np.flatnonzero(idx < limit)
        if active.size == 0:
            return
        yield active, idx[active]
        nidx, nstate = _jump_np(idx[active], state[active])
        idx[active] = nidx
        state[active] = nstate


def walk_chains(nxt, state, hi, visit=None):
    """Advance every chain position in place until ``nxt >= hi``.

    ``visit(live, idx)`` is called per round with the still-walking row
    selector and their current mapped indices (e.g. to XOR-accumulate a
    removal).  Returns the concatenation of all visited indices — the rows
    a decoder must re-test for purity.
    """
    touched = []
    while True:
        live = np.flatnonzero(nxt < hi)
        if live.size == 0:
            break
        idx = nxt[live]
        touched.append(idx.copy())
        if visit is not None:
            visit(live, idx)
        nn, ns = _jump_np(idx, state[live])
        nxt[live] = nn
        state[live] = ns
    return np.concatenate(touched) if touched else np.zeros(0, np.int64)


def item_indices_np(seed: int, m: int) -> np.ndarray:
    """All mapped indices < m for one item (exact chain).  int64 array."""
    out = []
    idx = np.zeros(1, dtype=np.int64)
    state = np.array([seed], dtype=np.uint64)
    while idx[0] < m:
        out.append(int(idx[0]))
        idx, state = _jump_np(idx, state)
    return np.asarray(out, dtype=np.int64)


def indices_matrix_np(seeds: np.ndarray, m: int, K: int | None = None) -> np.ndarray:
    """(n,) seeds -> (n, K) mapped indices < m, padded with m (vectorized)."""
    if K is None:
        K = kmax(m)
    n = seeds.shape[0]
    out = np.full((n, K), m, dtype=np.int64)
    idx = np.zeros(n, dtype=np.int64)
    state = seeds.astype(np.uint64).copy()
    for k in range(K):
        live = idx < m
        out[live, k] = idx[live]
        if not live.any():
            break
        idx, state = _jump_np(idx, state)
    return out


# ---------------------------------------------------------------------------
# Device path (JAX): identical chain on (hi, lo) uint32 pairs.
# ---------------------------------------------------------------------------
def _xs64_pair(h, l):
    # s ^= s << 13
    nh = h ^ ((h << jnp.uint32(13)) | (l >> jnp.uint32(19)))
    nl = l ^ (l << jnp.uint32(13))
    h, l = nh, nl
    # s ^= s >> 7
    nh = h ^ (h >> jnp.uint32(7))
    nl = l ^ ((l >> jnp.uint32(7)) | (h << jnp.uint32(25)))
    h, l = nh, nl
    # s ^= s << 17
    nh = h ^ ((h << jnp.uint32(17)) | (l >> jnp.uint32(15)))
    nl = l ^ (l << jnp.uint32(17))
    return nh, nl


def map_seeds_pair(words, key, nbytes: int | None = None):
    hi, lo = siphash24_pair(words, map_key(key), nbytes)
    return hi, lo | jnp.uint32(1)


def _jump_j(idx, h, l):
    """One skip-sampling step on device.  idx int32, (h, l) uint32 state."""
    h, l = _xs64_pair(h, l)
    rbits = (h >> jnp.uint32(8)).astype(jnp.float32)      # top 24 bits of u64
    r = rbits * jnp.float32(2.0 ** -24)
    t = jnp.float32(1.0) / jnp.sqrt(jnp.float32(1.0) - r)
    u = t - jnp.float32(1.0)
    f = jnp.float32(1.5) + idx.astype(jnp.float32)
    g = jnp.ceil(f * u).astype(jnp.int32)
    g = jnp.maximum(g, 1)
    return idx + g, h, l


def indices_matrix_j(seed_hi, seed_lo, m: int, K: int | None = None):
    """Device chain: (n,) uint32 seeds -> (n, K) int32 indices, pad = m."""
    if K is None:
        K = kmax(m)
    n = seed_hi.shape[0]
    idx = jnp.zeros(n, dtype=jnp.int32)
    h, l = seed_hi, seed_lo
    cols = []
    for _ in range(K):
        cols.append(idx)
        nidx, h, l = _jump_j(idx, h, l)
        # saturate at m: stops the chain (and prevents int32 overflow of
        # the ever-growing jump sizes once past the window).
        idx = jnp.minimum(nidx, jnp.int32(m))
    return jnp.stack(cols, axis=1)
