"""Wire encoding of coded-symbol streams (paper §6).

The ``count`` field of the i-th coded symbol of a set of N items is
concentrated around its expectation N·ρ(i); we transmit only the zig-zag
varint of (count − round(N·ρ(i))), averaging ~1 byte/symbol.  ``sum`` and
``checksum`` travel raw (ℓ and 8 bytes).

Two codecs share one body format:

* :func:`encode_frames` / :func:`decode_frames` — the protocol-layer frame:
  a 24-byte self-describing header ``(m, nbytes, n_items, start)`` so a
  receiver can consume any window of the universal stream without side
  channels.  This is what :class:`repro.protocol.Session` speaks.
* :func:`encode_stream` / :func:`decode_stream` — the original 16-byte
  header ``(m, nbytes, n_items)``; ``start`` is caller-supplied.  The
  Python API is kept for compatibility, but the body layout below is NOT
  readable by the pre-protocol interleaved encoder (and carries no version
  field): both ends must run the same revision.

A third codec composes frames rather than defining a new body:

* :func:`encode_shard_frames` / :func:`decode_shard_frames` — the sharded
  merged payload (one wire message carrying one frame per shard of a
  hash-partitioned key space), a magic+version outer header followed by
  shard-id'd extension headers each wrapping a standard protocol frame.
  This is what :class:`repro.protocol.ShardedSession` speaks.

The byte-exact layout of all three lives in ``docs/WIRE_FORMAT.md``.

Both are fully vectorized: the body is columnar (all sums, then all
checksums, then all varint count-deltas), packed and unpacked with numpy —
no per-symbol Python loop.  ``*_loop`` reference implementations produce
byte-identical output and exist for differential testing and the
``benchmarks/wirebench.py`` comparison.
"""
from __future__ import annotations

import struct

import numpy as np

from .mapping import rho
from .symbols import CodedSymbols

_FRAME_HDR = struct.Struct("<IIQQ")   # m, nbytes, n_items, start
_STREAM_HDR = struct.Struct("<IIQ")   # m, nbytes, n_items (legacy)
_MAX_VARINT = 10                      # ⌈64/7⌉ bytes bound a u64 varint


def _zigzag(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64)
    return ((u >> np.uint64(1)).astype(np.int64)) ^ -(u & np.uint64(1)).astype(np.int64)


def expected_counts(n_items: int, start: int, stop: int) -> np.ndarray:
    i = np.arange(start, stop, dtype=np.float64)
    return np.rint(n_items * rho(i)).astype(np.int64)


# ---------------------------------------------------------------------------
# Vectorized varint (LEB128) codec for uint64 vectors.
# ---------------------------------------------------------------------------
def _varint_encode_vec(u: np.ndarray) -> np.ndarray:
    """(n,) uint64 -> concatenated LEB128 bytes, one varint per value."""
    u = np.ascontiguousarray(u, dtype=np.uint64)
    n = u.shape[0]
    if n == 0:
        return np.zeros(0, np.uint8)
    shifts = (np.arange(_MAX_VARINT, dtype=np.uint64) * np.uint64(7))
    chunks = (u[:, None] >> shifts[None, :]) & np.uint64(0x7F)   # (n, 10)
    nb = np.ones(n, np.int64)                                    # bytes/value
    v = u >> np.uint64(7)
    for _ in range(_MAX_VARINT - 1):
        nb += (v != 0)
        v >>= np.uint64(7)
    cols = np.arange(_MAX_VARINT)[None, :]
    cont = cols < (nb[:, None] - 1)                              # MSB flags
    mat = (chunks | (cont.astype(np.uint64) << np.uint64(7))).astype(np.uint8)
    return mat[cols < nb[:, None]]                               # row-major


def _varint_decode_vec(buf: np.ndarray, n: int) -> tuple[np.ndarray, int]:
    """Decode exactly ``n`` varints from the head of ``buf`` (uint8 view).

    Returns (values uint64, bytes consumed).
    """
    if n == 0:
        return np.zeros(0, np.uint64), 0
    is_last = (buf & 0x80) == 0
    ends = np.flatnonzero(is_last)
    if ends.size < n:
        raise ValueError("truncated varint section")
    used = int(ends[n - 1]) + 1
    buf = buf[:used]
    is_last = is_last[:used]
    value_id = np.cumsum(np.r_[0, is_last[:-1].astype(np.int64)])
    starts = np.r_[np.int64(0), ends[: n - 1] + 1]
    pos = np.arange(used, dtype=np.int64) - starts[value_id]
    vals = np.zeros(n, np.uint64)
    np.bitwise_or.at(vals, value_id,
                     (buf & 0x7F).astype(np.uint64) << (np.uint64(7) * pos.astype(np.uint64)))
    return vals, used


def varint_count_bytes(counts: np.ndarray, n_items: int | None = None,
                       start: int = 0) -> int:
    """Size in bytes of the varint-delta encoding of a count vector."""
    counts = np.asarray(counts, dtype=np.int64)
    if n_items is None:
        n_items = int(abs(counts[0])) if counts.size else 0
    exp = expected_counts(n_items, start, start + counts.size)
    return int(_varint_encode_vec(_zigzag(counts - exp)).size)


# ---------------------------------------------------------------------------
# Columnar body: [sums: m·ℓ] [checks: m·8 LE] [count deltas: varints].
# ---------------------------------------------------------------------------
def _pack_body(sym: CodedSymbols, exp: np.ndarray) -> bytes:
    raw = np.ascontiguousarray(sym.sums).view(np.uint8).reshape(sym.m, 4 * sym.L)
    sums = np.ascontiguousarray(raw[:, : sym.nbytes])           # drop word pad
    checks = np.ascontiguousarray(sym.checks.astype("<u8"))
    deltas = _varint_encode_vec(_zigzag(sym.counts - exp))
    return sums.tobytes() + checks.tobytes() + deltas.tobytes()


def _unpack_body(buf: memoryview, pos: int, m: int, nbytes: int,
                 exp: np.ndarray) -> tuple[CodedSymbols, int]:
    L = (nbytes + 3) // 4
    sym = CodedSymbols.zeros(m, nbytes)
    raw = np.frombuffer(buf, np.uint8, count=m * nbytes, offset=pos)
    pos += m * nbytes
    padded = sym.sums.view(np.uint8).reshape(m, 4 * L)
    padded[:, :nbytes] = raw.reshape(m, nbytes)
    sym.checks[:] = np.frombuffer(buf, "<u8", count=m, offset=pos)
    pos += 8 * m
    z, used = _varint_decode_vec(
        np.frombuffer(buf, np.uint8, offset=pos), m)
    pos += used
    sym.counts[:] = _unzigzag(z) + exp
    return sym, pos


def _infer_n_items(sym: CodedSymbols, start: int, n_items: int | None) -> int:
    """Default n_items to |count of symbol 0|; only valid at start == 0."""
    if n_items is not None:
        return n_items
    if start != 0:
        raise ValueError("n_items is required for a nonzero-start window")
    return int(abs(sym.counts[0])) if sym.m else 0


# ---------------------------------------------------------------------------
# Protocol frames (self-describing windows of the universal stream).
# ---------------------------------------------------------------------------
def encode_frames(sym: CodedSymbols, start: int = 0,
                  n_items: int | None = None) -> bytes:
    """Serialize symbols [start, start+m) of the stream of a set with
    ``n_items`` elements into one self-describing frame."""
    n_items = _infer_n_items(sym, start, n_items)
    exp = expected_counts(n_items, start, start + sym.m)
    return _FRAME_HDR.pack(sym.m, sym.nbytes, n_items, start) + \
        _pack_body(sym, exp)


def decode_frames(data: bytes) -> tuple[CodedSymbols, int, int]:
    """Inverse of :func:`encode_frames`: (symbols, n_items, start)."""
    m, nbytes, n_items, start = _FRAME_HDR.unpack_from(data, 0)
    exp = expected_counts(n_items, start, start + m)
    sym, _ = _unpack_body(memoryview(data), _FRAME_HDR.size, m, nbytes, exp)
    return sym, n_items, start


# ---------------------------------------------------------------------------
# Sharded merged payload: one message, one shard-tagged frame per shard.
# ---------------------------------------------------------------------------
_MERGED_MAGIC = b"RSH1"               # rateless-sharded, layout revision 1
_MERGED_HDR = struct.Struct("<4sHH")  # magic, n_shards (total S), n_frames
_SHARD_EXT = struct.Struct("<HHI")    # shard_id, flags (0), frame byte length


def encode_shard_frames(frames, n_shards: int) -> bytes:
    """Merge per-shard protocol frames into one sharded wire payload.

    Parameters
    ----------
    frames: iterable of ``(shard_id, frame_bytes)`` where ``frame_bytes``
        is one :func:`encode_frames` output (a self-describing window of
        that shard's universal stream).  Settled shards are simply absent.
    n_shards: the total shard count S of the partition — carried in the
        outer header so a receiver can validate it against its own
        partition before consuming any frame.

    Returns the payload: outer header, then each frame prefixed with its
    shard-id'd extension header.  Frames keep the order given.
    """
    frames = list(frames)
    if not 0 < n_shards <= 0xFFFF:
        raise ValueError(f"n_shards must be in [1, 65535], got {n_shards}")
    parts = [_MERGED_HDR.pack(_MERGED_MAGIC, n_shards, len(frames))]
    for shard_id, frame in frames:
        if not 0 <= shard_id < n_shards:
            raise ValueError(f"shard_id {shard_id} outside [0, {n_shards})")
        parts.append(_SHARD_EXT.pack(shard_id, 0, len(frame)))
        parts.append(frame)
    return b"".join(parts)


def decode_shard_frames(data: bytes):
    """Inverse of :func:`encode_shard_frames`.

    Returns ``(n_shards, [(shard_id, symbols, n_items, start), ...])`` with
    one tuple per embedded frame, in payload order; ``n_items`` and
    ``start`` are per shard (each shard runs its own universal stream).
    Raises ``ValueError`` on a bad magic/version, a shard id outside the
    declared partition, or a truncated payload.
    """
    if len(data) < _MERGED_HDR.size:
        raise ValueError("truncated sharded payload (no header)")
    magic, n_shards, n_frames = _MERGED_HDR.unpack_from(data, 0)
    if magic != _MERGED_MAGIC:
        raise ValueError(f"not a sharded payload (magic {magic!r})")
    if n_shards == 0:
        raise ValueError("sharded payload declares zero shards")
    pos = _MERGED_HDR.size
    out = []
    for _ in range(n_frames):
        if pos + _SHARD_EXT.size > len(data):
            raise ValueError("truncated sharded payload (frame header)")
        shard_id, _flags, length = _SHARD_EXT.unpack_from(data, pos)
        pos += _SHARD_EXT.size
        if shard_id >= n_shards:
            raise ValueError(f"shard_id {shard_id} outside [0, {n_shards})")
        if pos + length > len(data):
            raise ValueError("truncated sharded payload (frame body)")
        sym, n_items, start = decode_frames(data[pos: pos + length])
        pos += length
        out.append((shard_id, sym, n_items, start))
    return n_shards, out


# ---------------------------------------------------------------------------
# Legacy stream codec (16-byte header, caller-supplied start).
# ---------------------------------------------------------------------------
def encode_stream(sym: CodedSymbols, start: int = 0,
                  n_items: int | None = None) -> bytes:
    """Serialize symbols [start, start+m) of a stream whose set has
    ``n_items`` elements (defaults to |count of symbol 0| when start==0)."""
    n_items = _infer_n_items(sym, start, n_items)
    exp = expected_counts(n_items, start, start + sym.m)
    return _STREAM_HDR.pack(sym.m, sym.nbytes, n_items) + _pack_body(sym, exp)


def decode_stream(data: bytes, start: int = 0) -> tuple[CodedSymbols, int]:
    """Inverse of :func:`encode_stream`.  Returns (symbols, n_items)."""
    m, nbytes, n_items = _STREAM_HDR.unpack_from(data, 0)
    exp = expected_counts(n_items, start, start + m)
    sym, _ = _unpack_body(memoryview(data), _STREAM_HDR.size, m, nbytes, exp)
    return sym, n_items


# ---------------------------------------------------------------------------
# Per-symbol loop reference (byte-identical output) — kept for differential
# tests and the wirebench vectorized-vs-loop comparison.
# ---------------------------------------------------------------------------
def _varint_encode_one(u: int) -> bytes:
    out = bytearray()
    while True:
        b = u & 0x7F
        u >>= 7
        if u:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _varint_decode_one(buf, pos: int):
    shift = 0
    val = 0
    while True:
        b = buf[pos]
        pos += 1
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, pos
        shift += 7


def encode_frames_loop(sym: CodedSymbols, start: int = 0,
                       n_items: int | None = None) -> bytes:
    """Per-symbol Python-loop encoder; output == :func:`encode_frames`."""
    n_items = _infer_n_items(sym, start, n_items)
    exp = expected_counts(n_items, start, start + sym.m)
    deltas = _zigzag(sym.counts - exp)
    raw = np.ascontiguousarray(sym.sums).view(np.uint8).reshape(sym.m, -1)
    sums, checks, varints = bytearray(), bytearray(), bytearray()
    for i in range(sym.m):
        sums += raw[i, : sym.nbytes].tobytes()
        checks += struct.pack("<Q", int(sym.checks[i]))
        varints += _varint_encode_one(int(deltas[i]))
    return _FRAME_HDR.pack(sym.m, sym.nbytes, n_items, start) + \
        bytes(sums) + bytes(checks) + bytes(varints)


def decode_frames_loop(data: bytes) -> tuple[CodedSymbols, int, int]:
    """Per-symbol Python-loop decoder; inverse of :func:`encode_frames`."""
    m, nbytes, n_items, start = _FRAME_HDR.unpack_from(data, 0)
    exp = expected_counts(n_items, start, start + m)
    L = (nbytes + 3) // 4
    sym = CodedSymbols.zeros(m, nbytes)
    buf = memoryview(data)
    pos = _FRAME_HDR.size
    for i in range(m):
        row = sym.sums[i].view(np.uint8)
        row[:nbytes] = np.frombuffer(buf[pos:pos + nbytes], np.uint8)
        pos += nbytes
    for i in range(m):
        sym.checks[i] = struct.unpack_from("<Q", data, pos)[0]
        pos += 8
    for i in range(m):
        delta, pos = _varint_decode_one(buf, pos)
        sym.counts[i] = _unzigzag(np.array([delta], np.uint64))[0] + exp[i]
    return sym, n_items, start
