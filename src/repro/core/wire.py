"""Wire encoding of coded-symbol streams (paper §6).

The ``count`` field of the i-th coded symbol of a set of N items is
concentrated around its expectation N·ρ(i); we transmit only the zig-zag
varint of (count − round(N·ρ(i))), averaging ~1 byte/symbol.  ``sum`` and
``checksum`` travel raw.  N rides with symbol 0.
"""
from __future__ import annotations

import struct

import numpy as np

from .mapping import rho
from .symbols import CodedSymbols


def _zigzag(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64)
    return ((u >> np.uint64(1)).astype(np.int64)) ^ -(u & np.uint64(1)).astype(np.int64)


def _varint_encode(u: int) -> bytes:
    out = bytearray()
    while True:
        b = u & 0x7F
        u >>= 7
        if u:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _varint_decode(buf: memoryview, pos: int):
    shift = 0
    val = 0
    while True:
        b = buf[pos]
        pos += 1
        val |= (b & 0x7F) << shift
        if not b & 0x80:
            return val, pos
        shift += 7


def expected_counts(n_items: int, start: int, stop: int) -> np.ndarray:
    i = np.arange(start, stop, dtype=np.float64)
    return np.rint(n_items * rho(i)).astype(np.int64)


def varint_count_bytes(counts: np.ndarray, n_items: int | None = None,
                       start: int = 0) -> int:
    """Size in bytes of the varint-delta encoding of a count vector."""
    counts = np.asarray(counts, dtype=np.int64)
    if n_items is None:
        n_items = int(abs(counts[0])) if counts.size else 0
    exp = expected_counts(n_items, start, start + counts.size)
    z = _zigzag(counts - exp)
    nz = np.maximum(z, 1).astype(np.float64)
    return int(np.sum(np.ceil(np.log2(nz + 1) / 7.0).clip(min=1)))


def encode_stream(sym: CodedSymbols, start: int = 0,
                  n_items: int | None = None) -> bytes:
    """Serialize symbols [start, start+m) of a stream whose set has
    ``n_items`` elements (defaults to |count of symbol 0| when start==0)."""
    if n_items is None:
        assert start == 0
        n_items = int(abs(sym.counts[0])) if sym.m else 0
    exp = expected_counts(n_items, start, start + sym.m)
    deltas = _zigzag(sym.counts - exp)
    head = struct.pack("<IIQ", sym.m, sym.nbytes, n_items)
    body = bytearray(head)
    raw_sums = np.ascontiguousarray(sym.sums).view(np.uint8).reshape(sym.m, -1)
    for i in range(sym.m):
        body += raw_sums[i, : 4 * sym.L].tobytes()[: 4 * sym.L]
        body += struct.pack("<Q", int(sym.checks[i]))
        body += _varint_encode(int(deltas[i]))
    return bytes(body)


def decode_stream(data: bytes, start: int = 0) -> tuple[CodedSymbols, int]:
    """Inverse of :func:`encode_stream`.  Returns (symbols, n_items)."""
    m, nbytes, n_items = struct.unpack_from("<IIQ", data, 0)
    pos = 16
    L = (nbytes + 3) // 4
    sym = CodedSymbols.zeros(m, nbytes)
    buf = memoryview(data)
    exp = expected_counts(n_items, start, start + m)
    for i in range(m):
        sym.sums[i] = np.frombuffer(buf[pos:pos + 4 * L], dtype=np.uint32)
        pos += 4 * L
        sym.checks[i] = struct.unpack_from("<Q", data, pos)[0]
        pos += 8
        delta, pos = _varint_decode(buf, pos)
        sym.counts[i] = _unzigzag(np.array([delta], dtype=np.uint64))[0] + exp[i]
    return sym, n_items
