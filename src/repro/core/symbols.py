"""Coded symbols (paper §3): sum, checksum, count — and their algebra.

``CodedSymbols`` is the host-side (numpy) container for a prefix of the
infinite coded-symbol sequence.  Subtraction is index-wise, and by linearity
``symbols(A) - symbols(B) == symbols(A △ B)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CodedSymbols:
    sums: np.ndarray    # (m, L) uint32 — XOR of mapped items' words
    checks: np.ndarray  # (m,)   uint64 — XOR of mapped items' keyed hashes
    counts: np.ndarray  # (m,)   int64  — signed #items mapped (A: +1, B: -1)
    nbytes: int         # item length ℓ in bytes

    @property
    def m(self) -> int:
        return self.sums.shape[0]

    @property
    def L(self) -> int:
        return self.sums.shape[1]

    @classmethod
    def zeros(cls, m: int, nbytes: int) -> "CodedSymbols":
        L = (nbytes + 3) // 4
        return cls(np.zeros((m, L), np.uint32), np.zeros(m, np.uint64),
                   np.zeros(m, np.int64), nbytes)

    def copy(self) -> "CodedSymbols":
        return CodedSymbols(self.sums.copy(), self.checks.copy(),
                            self.counts.copy(), self.nbytes)

    def prefix(self, m: int) -> "CodedSymbols":
        assert m <= self.m
        return CodedSymbols(self.sums[:m], self.checks[:m], self.counts[:m],
                            self.nbytes)

    def window(self, lo: int, hi: int | None = None) -> "CodedSymbols":
        """Zero-copy view of symbols [lo, hi) of this prefix.

        The view aliases this container's arrays (mutations are shared);
        call ``.copy()`` on the result for an isolated snapshot.
        """
        hi = self.m if hi is None else hi
        if not 0 <= lo <= hi <= self.m:
            raise IndexError(f"window [{lo}, {hi}) outside prefix of {self.m}")
        return CodedSymbols(self.sums[lo:hi], self.checks[lo:hi],
                            self.counts[lo:hi], self.nbytes)

    def __getitem__(self, s: slice) -> "CodedSymbols":
        if not isinstance(s, slice):
            raise TypeError("CodedSymbols supports slice indexing only")
        lo, hi, step = s.indices(self.m)
        if step != 1:
            raise ValueError("CodedSymbols slicing requires step 1")
        return self.window(lo, hi)

    def subtract(self, other: "CodedSymbols") -> "CodedSymbols":
        """self ⊕ other (paper's ⊕ is subtraction: XOR sums/checks, −counts)."""
        m = min(self.m, other.m)
        return CodedSymbols(self.sums[:m] ^ other.sums[:m],
                            self.checks[:m] ^ other.checks[:m],
                            self.counts[:m] - other.counts[:m], self.nbytes)

    def concat(self, other: "CodedSymbols") -> "CodedSymbols":
        assert self.nbytes == other.nbytes
        return CodedSymbols(np.concatenate([self.sums, other.sums]),
                            np.concatenate([self.checks, other.checks]),
                            np.concatenate([self.counts, other.counts]),
                            self.nbytes)

    def is_empty(self) -> np.ndarray:
        """(m,) bool — symbol has no items mapped (all fields zero)."""
        return (self.counts == 0) & (self.checks == np.uint64(0)) & \
               (self.sums == 0).all(axis=1)

    def wire_bytes(self) -> int:
        """Transmitted size with the paper's variable-length count encoding
        (§6): sum (ℓ) + checksum (8) + ~1 byte amortized varint count."""
        from .wire import varint_count_bytes
        return self.m * (self.nbytes + 8) + varint_count_bytes(self.counts)
