"""Baselines the paper compares against (§7): regular IBLT [12], MET-IBLT
[15], CPI/PinSketch [19, 6], and Merkle-trie state sync [38]."""
from .regular_iblt import RegularIBLT
from .met_iblt import MetIBLT
from .cpi import CPISketch
from .merkle import MerkleTrieSync

__all__ = ["RegularIBLT", "MetIBLT", "CPISketch", "MerkleTrieSync"]
