"""Regular (fixed-size) IBLT [Goodrich & Mitzenmacher 2011; Eppstein+ 2011].

Each item maps to k distinct cells of a fixed table of m cells (double
hashing).  Not rateless: m must be parameterized for the expected difference
size, decoding fails w.h.p. if d > m, and enlarging m rewrites every cell
(paper §3, Fig 3, Theorems A.1/A.2).
"""
from __future__ import annotations

import numpy as np

from ..hashing import DEFAULT_KEY, siphash24
from ..symbols import CodedSymbols


class RegularIBLT:
    def __init__(self, m: int, nbytes: int, k: int = 3, key=DEFAULT_KEY):
        self.m = m
        self.k = k
        self.nbytes = nbytes
        self.key = key
        self.table = CodedSymbols.zeros(m, nbytes)

    def _cells(self, words: np.ndarray) -> np.ndarray:
        """(n, k) distinct cell indices via double hashing."""
        h1 = siphash24(words, self.key, self.nbytes)
        h2 = siphash24(words, (self.key[0] ^ 0xA5A5A5A5, self.key[1]),
                       self.nbytes)
        a = (h1 % np.uint64(self.m)).astype(np.int64)
        b = (h2 % np.uint64(max(self.m - 1, 1))).astype(np.int64) + 1
        idx = (a[:, None] + np.arange(self.k)[None, :] * b[:, None]) % self.m
        # double hashing can still collide when gcd(b, m) > 1; nudge dups
        for j in range(1, self.k):
            dup = (idx[:, j:j + 1] == idx[:, :j]).any(axis=1)
            while dup.any():
                idx[dup, j] = (idx[dup, j] + 1) % self.m
                dup = (idx[:, j:j + 1] == idx[:, :j]).any(axis=1)
        return idx

    def insert(self, words: np.ndarray, sign: int = 1) -> None:
        hashes = siphash24(words, self.key, self.nbytes)
        idx = self._cells(words)
        from ..encoder import _xor_accumulate
        n = words.shape[0]
        rep = np.repeat(np.arange(n), self.k)
        _xor_accumulate(self.table.sums, self.table.checks, self.table.counts,
                        idx.reshape(-1), words[rep], hashes[rep],
                        np.full(n * self.k, sign, np.int64))

    def subtract(self, other: "RegularIBLT") -> CodedSymbols:
        return self.table.subtract(other.table)

    def decode(self, diff: CodedSymbols):
        """Peel; returns (items, sides, success)."""
        sym = diff.copy()
        rec_items, rec_sides = [], []
        for _ in range(10 * self.m):
            h = siphash24(sym.sums, self.key, self.nbytes)
            pure = np.flatnonzero((h == sym.checks) & (np.abs(sym.counts) == 1))
            if pure.size == 0:
                break
            i = pure[0]
            x = sym.sums[i:i + 1].copy()
            side = int(np.sign(sym.counts[i]))
            rec_items.append(x[0])
            rec_sides.append(side)
            hx = siphash24(x, self.key, self.nbytes)
            idx = self._cells(x)[0]
            from ..encoder import _xor_accumulate
            _xor_accumulate(sym.sums, sym.checks, sym.counts, idx,
                            np.repeat(x, self.k, axis=0),
                            np.repeat(hx, self.k),
                            np.full(self.k, -side, np.int64))
        ok = bool(sym.is_empty().all())
        items = np.stack(rec_items) if rec_items else \
            np.zeros((0, sym.L), np.uint32)
        return items, np.array(rec_sides, np.int8), ok


def reconcile_regular(words_a, words_b, m, nbytes, k=3, key=DEFAULT_KEY):
    A = RegularIBLT(m, nbytes, k, key)
    B = RegularIBLT(m, nbytes, k, key)
    if len(words_a):
        A.insert(words_a)
    if len(words_b):
        B.insert(words_b)
    return A.decode(A.subtract(B))
