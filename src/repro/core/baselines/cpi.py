"""Characteristic Polynomial Interpolation (CPI) set reconciliation
[Minsky, Trachtenberg, Zippel 2003] — the PinSketch/minisketch family's
ancestor.  Communication-optimal (m = d symbols), computation-heavy:
O(|A|·d) encode, O(d³) interpolation + root finding to decode (paper §2,
§7.2's 2–2000× computation-gap comparison).

Field: GF(p), p = 2³¹ − 1 (Mersenne; int64-safe products in numpy).  Items
are mapped into the field by a keyed hash, as PinSketch does for >8-byte
items; the recovered field elements are mapped back through a dictionary of
the parties' items (each party knows its own set).
"""
from __future__ import annotations

import numpy as np

from ..hashing import DEFAULT_KEY, siphash24

P = np.int64(2**31 - 1)


def _to_field(words: np.ndarray, key=DEFAULT_KEY, nbytes=None) -> np.ndarray:
    h = siphash24(words, key, nbytes)
    return ((h >> np.uint64(8)) % np.uint64(P - 2)).astype(np.int64) + 1


def _pow(base: np.ndarray, e: int) -> np.ndarray:
    r = np.ones_like(base)
    b = base % P
    while e:
        if e & 1:
            r = (r * b) % P
        b = (b * b) % P
        e >>= 1
    return r


def _inv(x: np.ndarray) -> np.ndarray:
    return _pow(x, int(P) - 2)


# ------------------------------------------------------ polynomial helpers
def _poly_mod(a: np.ndarray, f: np.ndarray) -> np.ndarray:
    """a mod f over GF(p); coefficients low-to-high, f monic."""
    a = a.copy() % P
    df = len(f) - 1
    if df == 0:
        return np.zeros(1, np.int64)   # mod a nonzero constant
    while len(a) - 1 >= df and len(a) > 1:
        c = a[-1] % P
        if c:
            a[-df - 1:] = (a[-df - 1:] - c * f) % P
        a = a[:-1]
    return a


def _poly_mul(a, b, f=None):
    r = np.convolve(a.astype(object), b.astype(object))
    r = np.array([int(x) % int(P) for x in r], dtype=np.int64)
    if f is not None:
        r = _poly_mod(r, f)
    return r


def _poly_gcd(a, b):
    a, b = a.copy(), b.copy()
    while len(b) > 1 or (len(b) == 1 and b[0] != 0):
        a = _poly_mod(a, _monic(b))
        a, b = b, a
        while len(a) > 1 and a[-1] == 0:
            a = a[:-1]
        while len(b) > 1 and b[-1] == 0:
            b = b[:-1]
    return _monic(a)


def _monic(f):
    f = f % P
    while len(f) > 1 and f[-1] == 0:
        f = f[:-1]
    if f[-1] != 1 and f[-1] != 0:
        f = (f * _inv(f[-1:])[0]) % P
    return f


def _poly_pow_mod(base, e: int, f):
    r = np.array([1], np.int64)
    b = _poly_mod(base.copy(), f)
    while e:
        if e & 1:
            r = _poly_mul(r, b, f)
        b = _poly_mul(b, b, f)
        e >>= 1
    return r


def _roots(f: np.ndarray, rng: np.random.Generator) -> list[int]:
    """All roots of squarefree f with only linear factors (Cantor–Zassenhaus
    equal-degree splitting, degree 1)."""
    f = _monic(f)
    d = len(f) - 1
    if d == 0:
        return []
    if d == 1:
        return [int((-f[0]) % P)]
    # split via gcd((x+r)^((p-1)/2) - 1, f)
    for _ in range(64):
        r = int(rng.integers(0, int(P)))
        g = _poly_pow_mod(np.array([r, 1], np.int64), (int(P) - 1) // 2, f)
        g = g.copy()
        g[0] = (g[0] - 1) % P
        h = _poly_gcd(f, g)
        if 0 < len(h) - 1 < d:
            q = _poly_div_exact(f, h)
            return _roots(h, rng) + _roots(q, rng)
    raise RuntimeError("root splitting failed")


def _poly_div_exact(a, b):
    """a / b (exact) over GF(p), both monic."""
    a = _monic(a.copy() % P)
    b = _monic(b.copy() % P)
    if len(b) == 1:          # division by the constant 1 (monic)
        return a
    out = np.zeros(len(a) - len(b) + 1, np.int64)
    while len(a) >= len(b):
        c = a[-1] % P
        out[len(a) - len(b)] = c
        a[-len(b):] = (a[-len(b):] - c * b) % P
        a = a[:-1]
    return _monic(out)


# ----------------------------------------------------------------- sketch
class CPISketch:
    """Alice-side: m evaluations of χ_A at fixed points z_1..z_m."""

    def __init__(self, m: int, nbytes: int, key=DEFAULT_KEY):
        self.m = m
        self.nbytes = nbytes
        self.key = key
        self.n_items = 0  # transmitted with the sketch (Minsky et al. §3)
        self.z = (np.arange(1, m + 1, dtype=np.int64) * 7919) % P
        self.evals = np.ones(m, dtype=np.int64)
        self.field_to_item: dict[int, np.ndarray] = {}

    def insert(self, words: np.ndarray) -> None:
        vals = _to_field(words, self.key, self.nbytes)
        self.n_items += len(vals)
        for v, w in zip(vals.tolist(), words):
            self.field_to_item[v] = w
        # evals *= prod (z - x)  — vectorized over points, loop over items
        for v in vals.tolist():
            self.evals = (self.evals * ((self.z - v) % P)) % P

    def decode_against(self, other: "CPISketch", d_bound: int | None = None):
        """Recover A△B given the two sketches (Bob holds `other` = his own).

        Returns (vals_only_a, vals_only_b, success).  O(m³) solve — the
        computation cost the paper's §7.2 comparison highlights.

        y(z) = χ_A/χ_B = P/Q with P = χ_{A∖B}·G, Q = χ_{B∖A}·G.  The degree
        difference Δ = deg P − deg Q = |A| − |B| is known (item counts
        travel with the sketch), so we solve for monic P of degree t and
        monic Q of degree t−Δ and strip the common factor G with a gcd.
        """
        m = self.m
        if d_bound is None:
            d_bound = m
        delta = self.n_items - other.n_items
        # d = da + db, da - db = delta  =>  da = (d+delta)/2
        t = max((d_bound + delta + 1) // 2, delta, 0)
        dq = t - delta
        if t + dq > m:
            return None, None, False   # sketch too short for this bound
        y = (self.evals * _inv(other.evals)) % P
        # Σ_{j<t} p_j z^j − y·Σ_{j<dq} q_j z^j = y·z^dq − z^t
        zp = np.ones((m, max(t, dq) + 1), np.int64)
        for j in range(1, zp.shape[1]):
            zp[:, j] = (zp[:, j - 1] * self.z) % P
        Amat = np.concatenate(
            [zp[:, :t], (-(y[:, None] * zp[:, :dq]) % P) % P], axis=1)
        rhs = ((y * zp[:, dq] - zp[:, t]) % P + P) % P
        sol, ok = _solve_mod(Amat, rhs)
        if not ok:
            return None, None, False
        pcoef = np.concatenate([sol[:t], [1]]).astype(np.int64)
        qcoef = np.concatenate([sol[t:], [1]]).astype(np.int64)
        rng = np.random.default_rng(0xC0FFEE)
        try:
            g = _poly_gcd(pcoef.copy(), qcoef.copy())
            pp = _poly_div_exact(_monic(pcoef), g)
            qq = _poly_div_exact(_monic(qcoef), g)
            ra = _roots(pp, rng)
            rb = _roots(qq, rng)
        except Exception:
            return None, None, False
        if len(ra) != len(pp) - 1 or len(rb) != len(qq) - 1:
            return None, None, False
        return ra, rb, True


def _solve_mod(A: np.ndarray, b: np.ndarray):
    """Gaussian elimination over GF(p); returns minimal-norm-ish solution.
    Handles rank deficiency by setting free vars to 0 (smaller true d)."""
    A = A % P
    b = b % P
    m, n = A.shape
    A = np.concatenate([A, b[:, None]], axis=1)
    row = 0
    piv_cols = []
    for col in range(n):
        piv = None
        for r in range(row, m):
            if A[r, col] != 0:
                piv = r
                break
        if piv is None:
            continue
        A[[row, piv]] = A[[piv, row]]
        A[row] = (A[row] * _inv(A[row, col:col + 1])[0]) % P
        mask = np.ones(m, bool)
        mask[row] = False
        factors = A[mask, col:col + 1]
        A[mask] = (A[mask] - factors * A[row]) % P
        piv_cols.append(col)
        row += 1
        if row == m:
            break
    # inconsistency?
    for r in range(row, m):
        if A[r, :n].max(initial=0) == 0 and A[r, n] != 0:
            return None, False
    x = np.zeros(n, np.int64)
    for r, c in enumerate(piv_cols):
        x[c] = A[r, n]
    return x, True
