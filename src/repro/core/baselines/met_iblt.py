"""MET-IBLT (multi-edge-type IBLT) — rate-compatible IBLT [Lázaro & Matuz
2023].  Our reimplementation (no open-source original, as the paper notes in
§7.2).

Cells are split into classes; items map to each class with a class-specific
degree.  The cell layout is *nested*: the table for difference budget
d_{i+1} extends the table for d_i, so a prefix is usable for smaller d —
rate-compatible at the *pre-selected* d values only (the paper's §2
criticism: off the grid of optimized d's, its overhead degrades 4–10×,
and there is no practical incremental encoder).

We use the degree/ratio structure from [15, §V-A]: three edge types with
cell-class ratios ~[0.4, 0.4, 0.2] and per-class item degrees [1, 2, 1] at
each rate step; steps double the table: m_i = m_0·2^i.
"""
from __future__ import annotations

import numpy as np

from ..hashing import DEFAULT_KEY, siphash24
from ..symbols import CodedSymbols
from ..encoder import _xor_accumulate

_RATIOS = np.array([0.4, 0.4, 0.2])
_DEGREES = np.array([1, 2, 1])


class MetIBLT:
    """Nested MET-IBLT sized by rate steps: m(step) = m0 * 2**step."""

    def __init__(self, m0: int, steps: int, nbytes: int, key=DEFAULT_KEY):
        self.m0 = m0
        self.steps = steps
        self.nbytes = nbytes
        self.key = key
        self.layout = []  # (start, size) per (step, class)
        start = 0
        for s in range(steps):
            m_s = m0 * (2 ** s) - (m0 * (2 ** (s - 1)) if s else 0)
            sizes = np.maximum((np.floor(_RATIOS * m_s)).astype(int), 1)
            sizes[-1] = m_s - sizes[:-1].sum()
            for c, sz in enumerate(sizes):
                self.layout.append((start, int(sz), c))
                start += int(sz)
        self.m = start
        self.table = CodedSymbols.zeros(self.m, nbytes)

    def _cells(self, words: np.ndarray):
        """All (row, cell) pairs for a batch of items."""
        n = words.shape[0]
        rows, cells = [], []
        for li, (start, size, cls) in enumerate(self.layout):
            deg = _DEGREES[cls]
            for r in range(deg):
                h = siphash24(words, (self.key[0] ^ (li * 1315423911 + r),
                                      self.key[1] ^ 0x5DEECE66D), self.nbytes)
                cells.append(start + (h % np.uint64(size)).astype(np.int64))
                rows.append(np.arange(n))
        return np.concatenate(rows), np.concatenate(cells)

    def insert(self, words: np.ndarray, sign: int = 1) -> None:
        hashes = siphash24(words, self.key, self.nbytes)
        rows, cells = self._cells(words)
        _xor_accumulate(self.table.sums, self.table.checks, self.table.counts,
                        cells, words[rows], hashes[rows],
                        np.full(rows.size, sign, np.int64))

    def prefix(self, step: int) -> CodedSymbols:
        """Cells usable at rate step `step` (nested prefix)."""
        end = self.m0 * (2 ** step)
        end = min(end, self.m)
        return self.table.prefix(end)

    def decode(self, diff: CodedSymbols):
        sym = diff.copy()
        m_used = sym.m
        rec_items, rec_sides = [], []
        for _ in range(10 * m_used + 10):
            h = siphash24(sym.sums, self.key, self.nbytes)
            pure = np.flatnonzero((h == sym.checks) & (np.abs(sym.counts) == 1))
            if pure.size == 0:
                break
            i = pure[0]
            x = sym.sums[i:i + 1].copy()
            side = int(np.sign(sym.counts[i]))
            rec_items.append(x[0])
            rec_sides.append(side)
            hx = siphash24(x, self.key, self.nbytes)
            rows, cells = self._cells(x)
            keep = cells < m_used
            cells = cells[keep]
            _xor_accumulate(sym.sums, sym.checks, sym.counts, cells,
                            np.repeat(x, cells.size, axis=0),
                            np.repeat(hx, cells.size),
                            np.full(cells.size, -side, np.int64))
        ok = bool(sym.is_empty().all())
        items = np.stack(rec_items) if rec_items else \
            np.zeros((0, sym.L), np.uint32)
        return items, np.array(rec_sides, np.int8), ok
