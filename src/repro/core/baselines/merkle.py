"""Merkle-trie state synchronization cost simulator (Ethereum "state heal",
paper §2 & §7.3).

Models the binary hash trie over the keyed hashes of a set's items and
computes the exact sync transcript between two parties: starting from the
root, each round the requester fetches the children of every differing node
(lock-step descent — the O(log N) round-trip cost the paper §7.3 measures);
when a differing subtrie bottoms out, its leaves are transferred.  Returns
(bytes, rounds, differing leaves) — the quantities behind Figs. 11/12/15/16.
"""
from __future__ import annotations

import numpy as np

from ..hashing import DEFAULT_KEY, siphash24

HASH_BYTES = 32          # production tries use 32-byte node hashes
REQUEST_OVERHEAD = 16    # per-node request framing


class MerkleTrieSync:
    def __init__(self, words: np.ndarray, nbytes: int, key=DEFAULT_KEY,
                 fanout_bits: int = 4):
        """fanout_bits=4 matches Geth's 16-ary trie."""
        self.nbytes = nbytes
        self.fb = fanout_bits
        self.keys = np.sort(siphash24(words, key, nbytes)) if len(words) \
            else np.zeros(0, np.uint64)

    def _range(self, prefix: int, depth: int):
        """[lo, hi) of sorted keys under `prefix` at `depth` nibbles."""
        bits = self.fb * depth
        if bits == 0:
            return 0, len(self.keys)
        lo = np.uint64(prefix) << np.uint64(64 - bits)
        if bits >= 64:
            hi = lo + np.uint64(1)
        else:
            hi = (np.uint64(prefix) + np.uint64(1)) << np.uint64(64 - bits)
        return (int(np.searchsorted(self.keys, lo, side="left")),
                int(np.searchsorted(self.keys, hi, side="left")) if
                prefix + 1 < (1 << bits) else len(self.keys))

    def _node_hash(self, prefix: int, depth: int):
        lo, hi = self._range(prefix, depth)
        return hash(self.keys[lo:hi].tobytes())

    def _node_count(self, prefix: int, depth: int) -> int:
        lo, hi = self._range(prefix, depth)
        return hi - lo

    def sync_cost(self, other: "MerkleTrieSync", value_bytes: int):
        """Transcript for self (stale) pulling other's (fresh) state.

        Returns (bytes_moved, round_trips, differing_leaves)."""
        bytes_moved = HASH_BYTES
        rounds = 1
        if self._node_hash(0, 0) == other._node_hash(0, 0):
            return bytes_moved, rounds, 0
        frontier = [(0, 0)]
        leaves = 0
        max_depth = 64 // self.fb
        while frontier:
            rounds += 1
            nxt = []
            for prefix, depth in frontier:
                # bottomed-out subtrie: transfer its differing leaves
                if depth >= max_depth or \
                        max(self._node_count(prefix, depth),
                            other._node_count(prefix, depth)) <= 1:
                    lo_a, hi_a = self._range(prefix, depth)
                    lo_b, hi_b = other._range(prefix, depth)
                    a = set(self.keys[lo_a:hi_a].tolist())
                    b = set(other.keys[lo_b:hi_b].tolist())
                    d = len(a ^ b)
                    leaves += d
                    bytes_moved += d * (self.nbytes + value_bytes)
                    continue
                # fetch children hashes of the differing node
                for c in range(1 << self.fb):
                    child = (prefix << self.fb) | c
                    ca = self._node_count(child, depth + 1)
                    cb = other._node_count(child, depth + 1)
                    if ca == 0 and cb == 0:
                        continue
                    bytes_moved += HASH_BYTES + REQUEST_OVERHEAD
                    if self._node_hash(child, depth + 1) != \
                            other._node_hash(child, depth + 1):
                        nxt.append((child, depth + 1))
            frontier = nxt
        return bytes_moved, rounds, leaves
