"""Incremental stream decoding (paper §4.1 protocol).

Alice streams coded symbols; Bob subtracts his own (locally generated)
symbols index-wise and peels as symbols arrive, terminating as soon as
symbol 0 empties (ρ(0)=1 ⇒ it is decoded last).  Already-recovered items are
XOR-ed out of newly arriving symbols by extending their mapping chains — the
decoder mirror of the encoder's incrementality.

With ``backend="device"`` the per-window peel runs through the
:mod:`repro.kernels.peel` wave decoder instead of the numpy loop: the
residual prefix goes to the device, recovered items and the peeled residual
come back, and the host keeps only the chain bookkeeping that extends
recovered items into future windows.  Both engines maintain the identical
``work``/recovered state, so the backend can be switched between windows.
"""
from __future__ import annotations

import numpy as np

from .decoder import resolve_backend
from .encoder import Encoder, _xor_accumulate
from .hashing import DEFAULT_KEY, siphash24
from .mapping import map_seeds, walk_chains
from .symbols import CodedSymbols


class StreamDecoder:
    """Decodes A △ B from an incrementally received prefix of A's stream.

    ``local`` is Bob's encoder for his set B (its prefix is extended in lock
    step and subtracted).  Pass ``local=None`` to decode a raw set stream.
    ``backend``: "host" | "device" | "auto" peel engine; ``max_diff`` bounds
    the device decoder's fixed recovered-item buffers (the default — the
    prefix length — cannot overflow, since a peel recovers at most one
    item per symbol; see :func:`repro.kernels.ops.decode_device`).
    """

    def __init__(self, nbytes: int, local: Encoder | None = None,
                 key=DEFAULT_KEY, backend: str = "host",
                 max_diff: int | None = None):
        self.nbytes = nbytes
        self.key = key
        self.local = local
        self.backend = resolve_backend(backend)
        self.max_diff = max_diff
        self.work = CodedSymbols.zeros(0, nbytes)
        self.rec_items = np.zeros((0, (nbytes + 3) // 4), np.uint32)
        self.rec_hashes = np.zeros(0, np.uint64)
        self.rec_sides = np.zeros(0, np.int8)
        # chain positions of recovered items at index == self.work.m
        self._rnext = np.zeros(0, np.int64)
        self._rstate = np.zeros(0, np.uint64)
        self.symbols_received = 0
        self.decoded_at: int | None = None  # symbols used at first decode

    # ------------------------------------------------------------------
    @property
    def decoded(self) -> bool:
        if self.work.m == 0:
            return False
        return bool(self.work.is_empty()[0])

    def receive(self, sym: CodedSymbols) -> bool:
        """Feed symbols [m, m+sym.m) of A's stream.  Returns `decoded`."""
        old, m = self.absorb(sym)
        if self.backend == "device":
            self._peel_device(old, m)
        else:
            self.peel_window(old, m)
        return self.mark_decoded()

    def absorb(self, sym: CodedSymbols) -> tuple[int, int]:
        """Ingest a window without peeling: subtract the local symbols,
        append to the residual ``work`` prefix, and extend every already-
        recovered item's chain through the new rows.

        Returns ``(old, new)`` — the prefix length before and after —
        for a later :meth:`peel_window` / batched device decode.  Splitting
        ingest from peel is what lets a sharded session absorb every
        shard's frame first and then decode all shards in one batched
        device call; plain sessions use :meth:`receive`, which is
        ``absorb`` + peel + :meth:`mark_decoded`.
        """
        old = self.work.m
        if self.local is not None:
            loc = self.local.window(old, old + sym.m)
            sym = sym.subtract(loc)
        self.work = self.work.concat(sym.copy())
        self.symbols_received = self.work.m
        m = self.work.m
        # extend recovered items' chains through the new rows
        self._walk(self.rec_items, self.rec_hashes, self.rec_sides,
                   self._rnext, self._rstate, m)
        return old, m

    def peel_window(self, old: int, m: int) -> None:
        """Host-peel rows [old, m) of the residual (plus whatever their
        removals touch) — the exact engine, also the per-shard overflow
        fallback of the batched device path."""
        self._peel(np.arange(old, m, dtype=np.int64))

    def mark_decoded(self, at: int | None = None) -> bool:
        """Record the ρ(0)=1 termination point once; returns ``decoded``.

        ``at`` pins the recorded prefix length to the decode that actually
        produced the signal — a pipelined engine absorbs the next window
        *before* the previous decode's result lands, so at that moment
        ``symbols_received`` already includes speculative overshoot that
        the termination did not need.
        """
        done = self.decoded
        if done and self.decoded_at is None:
            self.decoded_at = self.symbols_received if at is None \
                else min(at, self.symbols_received)
        return done

    # ------------------------------------------------------------------
    def _walk(self, items, hashes, sides, nxt, state, hi):
        def remove(live, idx):
            _xor_accumulate(self.work.sums, self.work.checks,
                            self.work.counts, idx, items[live], hashes[live],
                            -sides[live].astype(np.int64))

        return walk_chains(nxt, state, hi, remove)

    def _peel(self, cand: np.ndarray) -> None:
        m = self.work.m
        while cand.size:
            cand = np.unique(cand)
            h = siphash24(self.work.sums[cand], self.key, self.nbytes)
            pure = cand[(h == self.work.checks[cand]) &
                        (self.work.counts[cand] != 0)]
            if pure.size == 0:
                return
            items = self.work.sums[pure]
            hashes = self.work.checks[pure]
            sides = np.sign(self.work.counts[pure]).astype(np.int8)
            _, first = np.unique(hashes, return_index=True)
            items, hashes, sides = items[first], hashes[first], sides[first]
            fresh = ~np.isin(hashes, self.rec_hashes)
            items, hashes, sides = items[fresh], hashes[fresh], sides[fresh]
            if items.shape[0] == 0:
                return
            n = items.shape[0]
            nxt = np.zeros(n, np.int64)
            state = map_seeds(items, self.key, self.nbytes).copy()
            cand = self._walk(items, hashes, sides, nxt, state, m)
            self.rec_items = np.concatenate([self.rec_items, items])
            self.rec_hashes = np.concatenate([self.rec_hashes, hashes])
            self.rec_sides = np.concatenate([self.rec_sides, sides])
            self._rnext = np.concatenate([self._rnext, nxt])
            self._rstate = np.concatenate([self._rstate, state])

    def _peel_device(self, old: int, m: int) -> None:
        """Wave-peel the whole residual prefix on device and merge.

        ``self.work`` already has previously recovered items removed, so
        the device decoder starts from a clean residual; it returns the
        newly recovered items plus the peeled residual, and the host walks
        each new item's chain to its first index ≥ m so later windows keep
        extending it (`_walk`).  A ``max_diff`` overflow falls back to the
        exact host peel for this window.
        """
        from repro.kernels.ops import decode_device, host_symbols_to_device
        res = decode_device(*host_symbols_to_device(self.work),
                            nbytes=self.nbytes, key=self.key,
                            max_diff=self.max_diff)
        if res.overflow:
            self.peel_window(old, m)
            return
        self.merge_device_result(res)

    def merge_device_result(self, res) -> None:
        """Fold a successful :func:`repro.kernels.ops.decode_device` (or one
        unit of ``decode_device_batched``) outcome into host state: adopt
        the peeled residual as ``work`` and register each newly recovered
        item with its chain advanced to the first index ≥ the prefix length
        (so later windows keep extending it).  ``res.overflow`` must be
        False — overflowed decodes leave state untouched and the caller
        falls back to :meth:`peel_window`.

        Tail-aware: the decode may cover only a *prefix* of the current
        ``work`` (``res.residual.m ≤ work.m``) — a pipelined engine absorbs
        the next window while the device result is still in flight.  The
        rows absorbed after the dispatch are kept and each newly recovered
        item is removed from them by walking its chain through the tail,
        exactly as :meth:`absorb` does for previously recovered items.
        """
        assert not res.overflow
        if res.items.shape[0] == 0:
            return
        m0 = res.residual.m
        assert m0 <= self.work.m
        if m0 < self.work.m:
            self.work = res.residual.concat(self.work.window(m0))
        else:
            self.work = res.residual
        nxt = np.zeros(res.items.shape[0], np.int64)
        state = map_seeds(res.items, self.key, self.nbytes).copy()
        walk_chains(nxt, state, m0)  # position each chain at first idx >= m0
        # remove the new items from any tail rows and leave every chain
        # parked at the first index >= work.m for future windows
        self._walk(res.items, res.hashes, res.sides, nxt, state, self.work.m)
        self.rec_items = np.concatenate([self.rec_items, res.items])
        self.rec_hashes = np.concatenate([self.rec_hashes, res.hashes])
        self.rec_sides = np.concatenate([self.rec_sides, res.sides])
        self._rnext = np.concatenate([self._rnext, nxt])
        self._rstate = np.concatenate([self._rstate, state])

    # ------------------------------------------------------------------
    def result(self):
        """(items_exclusive_to_A, items_exclusive_to_B) as uint32 words."""
        a = self.rec_items[self.rec_sides > 0]
        b = self.rec_items[self.rec_sides < 0]
        return a, b
