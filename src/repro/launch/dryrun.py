import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init).
"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, and extract the roofline raw terms from the compiled
artifact (memory analysis, cost analysis, collective bytes from HLO).

  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh multipod

Results land in out/dryrun/<arch>__<shape>__<mesh>.json (cached; delete to
re-run).  --all orchestrates one subprocess per cell so a pathological cell
cannot poison the rest (and compile memory is returned to the OS).
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "out", "dryrun")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    # e.g.:  %all-reduce.5 = bf16[2048,7168]{1,0} all-reduce(...)
    pat = re.compile(
        r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)=]*?\s("
        + "|".join(_COLLECTIVES) + r")\(")
    for m in pat.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt == "tuple":
            continue
        nbytes = _DTYPE_BYTES.get(dt, 4)
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        out[kind]["count"] += 1
        out[kind]["bytes"] += numel * nbytes
    # tuple-shaped collectives: "= (bf16[..], bf16[..]) all-reduce("
    pat2 = re.compile(r"=\s*\(([^)]*)\)[^=]*?\s("
                      + "|".join(_COLLECTIVES) + r")\(")
    for m in pat2.finditer(hlo_text):
        kind = m.group(2)
        total = 0
        for dt, dims in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", m.group(1)):
            nbytes = _DTYPE_BYTES.get(dt, 4)
            numel = 1
            for d in dims.split(","):
                if d:
                    numel *= int(d)
            total += numel * nbytes
        if total:
            out[kind]["count"] += 1
            out[kind]["bytes"] += total
    return out


def abstract_init(model, key):
    """(param ShapeDtypeStructs, param PartitionSpecs) without allocating."""
    import jax
    holder = []

    def run(k):
        p, s = model.init(k)
        holder.append(s)
        return p

    shapes = jax.eval_shape(run, key)
    return shapes, holder[0]


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (fn, in_shapes, in_shardings, out_shardings)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model, input_specs
    from repro.train.loop import make_opt_config, make_train_step
    from repro.train.optim import init_state, state_specs

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, mesh)
    ns = lambda spec_tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    key = jax.random.key(0)
    p_shapes, p_specs = abstract_init(model, key)
    batch_shapes, batch_pspecs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        opt_cfg = make_opt_config(cfg)
        o_shapes = jax.eval_shape(lambda p: init_state(opt_cfg, p), p_shapes)
        o_specs = state_specs(opt_cfg, p_specs)
        step = make_train_step(model, opt_cfg,
                               microbatches=cfg.microbatches)
        in_shardings = (ns(p_specs), ns(o_specs), ns(batch_pspecs))
        out_shardings = (ns(p_specs), ns(o_specs), None)
        args = (p_shapes, o_shapes, batch_shapes)
        fn = step
    elif shape.kind == "prefill":
        fn = model.prefill
        in_shardings = (ns(p_specs), ns(batch_pspecs))
        out_shardings = None
        args = (p_shapes, batch_shapes)
    else:  # decode
        def fn(params, tokens, caches, pos):
            return model.decode_step(params, tokens, caches, pos)
        cache_pspecs = batch_pspecs["caches"]
        in_shardings = (ns(p_specs), ns(batch_pspecs["tokens"]),
                        ns(cache_pspecs), ns(batch_pspecs["pos"]))
        out_shardings = (None, ns(cache_pspecs))
        args = (p_shapes, batch_shapes["tokens"], batch_shapes["caches"],
                batch_shapes["pos"])
    return fn, args, in_shardings, out_shardings, mesh


def run_cell(arch: str, shape_name: str, mesh_name: str) -> dict:
    import jax
    multi_pod = mesh_name == "multipod"
    t0 = time.time()
    fn, args, in_sh, out_sh, mesh = build_cell(arch, shape_name, multi_pod)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = parse_collectives(text)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", -1)) if cost else -1,
        "bytes_per_device": float(cost.get("bytes accessed", -1))
        if cost else -1,
        "memory": {
            k: int(getattr(mem, k, -1)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
        } if mem is not None else {},
        "collectives": coll,
        "hlo_bytes": len(text),
    }
    print(json.dumps({k: v for k, v in result.items() if k != "collectives"},
                     indent=None), flush=True)
    print("collectives:", json.dumps(coll), flush=True)
    print("memory_analysis:", result["memory"], flush=True)
    return result


def cell_path(arch, shape, mesh_name):
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000,
                    help="per-cell subprocess timeout (s) in --all mode")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    if args.all:
        from repro.configs import cells
        todo = [(a, s, args.mesh) for a, s, _ in cells()]
        failures = []
        for a, s, m in todo:
            path = cell_path(a, s, m)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {a} {s} {m}", flush=True)
                continue
            print(f"[run] {a} {s} {m}", flush=True)
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                 "--shape", s, "--mesh", m],
                capture_output=True, text=True, timeout=args.timeout,
                env={**os.environ, "PYTHONPATH":
                     os.environ.get("PYTHONPATH", "src")})
            if proc.returncode != 0:
                failures.append((a, s, m))
                print(f"[FAIL] {a} {s} {m}\n{proc.stdout[-2000:]}"
                      f"\n{proc.stderr[-2000:]}", flush=True)
        print(f"done; {len(failures)} failures: {failures}", flush=True)
        sys.exit(1 if failures else 0)

    result = run_cell(args.arch, args.shape, args.mesh)
    with open(cell_path(args.arch, args.shape, args.mesh), "w") as f:
        json.dump(result, f, indent=2)


if __name__ == "__main__":
    main()
