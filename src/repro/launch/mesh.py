"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis is
data-parallel by default (only the gradient reduction crosses the DCN) and
can be repurposed for pipeline parallelism (train/pipeline.py).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 takes axis_types (and defaults changed); older jax has
    # neither the kwarg nor jax.sharding.AxisType.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests, examples)."""
    return _make_mesh((data, model), ("data", "model"))
