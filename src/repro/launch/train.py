"""End-to-end training driver with checkpoint/restart fault tolerance and
Rateless-IBLT state repair.

    python -m repro.launch.train --arch yi-9b --smoke --steps 50
    python -m repro.launch.train --arch yi-9b --smoke --steps 50 \
        --fail-at 20 --peer-dir /ckpts/healthy   # crash + IBLT repair demo

Recovery path on start: restore local checkpoint -> verify chunk digests ->
if stale/corrupt and a peer is configured, reconcile only the differing
chunks from the peer (repro.checkpoint.reconcile) -> resume at the stored
step with deterministic data skip-ahead (straggler/replacement workers
resume mid-epoch without replaying samples).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def synthetic_batch(cfg, step, batch, seq):
    """Deterministic data pipeline with O(1) skip-ahead: batch t is a pure
    function of (arch, t), so a restarted worker resumes exactly."""
    import jax
    import jax.numpy as jnp
    key = jax.random.fold_in(jax.random.key(hash(cfg.name) % 2**31), step)
    toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision_stub":
        out["patches"] = jnp.zeros((batch, cfg.n_patches, cfg.d_model),
                                   jnp.float32)
    if cfg.frontend == "audio_stub":
        out["frames"] = jnp.zeros((batch, cfg.encoder_frames, cfg.d_model),
                                  jnp.float32)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="out/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a crash after this step (exit 17)")
    ap.add_argument("--peer-dir", default=None,
                    help="healthy peer checkpoint dir for IBLT repair")
    args = ap.parse_args()

    import jax
    from repro.checkpoint.manager import CheckpointStore
    from repro.configs import get_config, smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.train.loop import (init_train_state, make_opt_config,
                                  make_train_step)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh()
    model = build_model(cfg, mesh)
    opt_cfg = make_opt_config(cfg, total_steps=args.steps)
    params, opt_state, _ = init_train_state(model, opt_cfg, jax.random.key(0))
    store = CheckpointStore(args.ckpt_dir)

    # ---- recovery -----------------------------------------------------
    start = 0
    man = store.manifest()
    if man is not None:
        bad = store.verify()
        if bad and args.peer_dir:
            print(f"[recover] {len(bad)} corrupt chunks; reconciling from "
                  "peer via Rateless IBLT", flush=True)
            from repro.checkpoint.reconcile import PeerEndpoint, sync_from_peer
            peer = PeerEndpoint(CheckpointStore(args.peer_dir))
            rep = sync_from_peer(store, peer)
            print(f"[recover] fetched {rep.chunks_fetched} chunks, "
                  f"{rep.total_bytes/1e6:.2f} MB vs naive "
                  f"{rep.naive_bytes/1e6:.2f} MB "
                  f"({rep.savings:.1f}x saved)", flush=True)
        elif bad:
            raise SystemExit(f"corrupt checkpoint, no peer: {bad[:4]}")
        struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt_state})
        state = store.restore(struct)
        params, opt_state = state["params"], state["opt"]
        start = int(store.manifest()["step"])
        print(f"[recover] resumed from step {start}", flush=True)

    step_fn = jax.jit(make_train_step(model, opt_cfg))
    t0 = time.time()
    for t in range(start, args.steps):
        batch = synthetic_batch(cfg, t, args.batch, args.seq)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            store.save(t + 1, {"params": jax.device_get(params),
                               "opt": jax.device_get(opt_state)})
            print(f"[ckpt] step {t+1}", flush=True)
        if args.fail_at and t + 1 == args.fail_at:
            print("[failure-injection] simulating crash", flush=True)
            raise SystemExit(17)
    print("done", flush=True)


if __name__ == "__main__":
    main()
