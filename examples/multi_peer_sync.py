"""Universality in action: ONE peer serves the SAME coded-symbol stream to
replicas at very different staleness — no per-replica encoding work
(paper §4.1: "the same sequence ... can be used to reconcile any number of
differences with any other set").

Each replica opens its own ``Session`` with its own pacing policy, and all
of them pull byte frames from the single shared ``SymbolStream``: the
peer's prefix cache is extended once, by whichever session reaches
furthest, and every window served is a zero-copy view of it.

    PYTHONPATH=src python examples/multi_peer_sync.py
"""
import numpy as np

from repro.core import Sketch
from repro.protocol import FixedBlock, Session, SymbolStream, run_session

rng = np.random.default_rng(7)
state = [bytes([0]) + rng.bytes(15) for _ in range(50_000)]

peer = SymbolStream.from_items(state, nbytes=16)    # encodes ONCE

for staleness in (2, 40, 700):
    replica_state = state[:-staleness] + \
        [bytes([9]) + rng.bytes(15) for _ in range(3)]
    replica = Sketch.from_items(replica_state, nbytes=16)
    session = Session(local=replica, pacing=FixedBlock(16))
    report = run_session(peer, session, wire=True)   # same universal stream
    d = staleness + 3
    print(f"staleness d={d}: decoded with {report.symbols_used} symbols "
          f"({report.bytes_received} wire bytes, overhead "
          f"{report.overhead(d):.2f}x) from the shared stream")

print(f"peer cache holds {peer.m} symbols — extended once, served thrice")
