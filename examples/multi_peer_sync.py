"""Universality in action: ONE peer serves the SAME coded-symbol stream to
replicas at very different staleness — no per-replica encoding work
(paper §4.1: "the same sequence ... can be used to reconcile any number of
differences with any other set").

    PYTHONPATH=src python examples/multi_peer_sync.py
"""
import numpy as np

from repro.core import CodedSymbols, Sketch, StreamDecoder

rng = np.random.default_rng(7)
state = [bytes([0]) + rng.bytes(15) for _ in range(50_000)]

peer = Sketch.from_items(state, nbytes=16)          # encodes ONCE

for staleness in (2, 40, 700):
    replica_state = state[:-staleness] + \
        [bytes([9]) + rng.bytes(15) for _ in range(3)]
    replica = Sketch.from_items(replica_state, nbytes=16)
    dec = StreamDecoder(16, local=replica)
    m = 0
    while not dec.decoded:
        sym = peer.symbols(m + 16)                  # same universal stream
        dec.receive(CodedSymbols(sym.sums[m:], sym.checks[m:],
                                 sym.counts[m:], 16))
        m += 16
    need, stale_items = dec.result()
    d = staleness + 3
    print(f"staleness d={d}: decoded with {dec.decoded_at} symbols "
          f"(overhead {dec.decoded_at/d:.2f}x) from the shared stream")
