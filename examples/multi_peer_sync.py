"""Universality in action: ONE peer serves the SAME coded-symbol stream to
replicas at very different staleness — no per-replica encoding work
(paper §4.1: "the same sequence ... can be used to reconcile any number of
differences with any other set").

Every replica opens its own ``Session`` with its own pacing policy, and a
single ``ReconcileEngine`` drives all of them *concurrently*: each tick it
plans every replica's pending (peer, window) decode work, coalesces it
into one batched decode per shape bucket, and — in its double-buffered
pipeline — absorbs the next round of frames while the previous round's
decode is still in flight.  The peer's prefix cache is extended once, by
whichever session reaches furthest per tick, and every window served is a
zero-copy view of it.

    PYTHONPATH=src python examples/multi_peer_sync.py
"""
import numpy as np

from repro.core import Sketch
from repro.protocol import FixedBlock, ReconcileEngine, Session, SymbolStream

rng = np.random.default_rng(7)
state = [bytes([0]) + rng.bytes(15) for _ in range(50_000)]

peer = SymbolStream.from_items(state, nbytes=16)    # encodes ONCE

engine = ReconcileEngine()                          # all replicas, one loop
staleness = (2, 40, 700)
for lost in staleness:
    replica_state = state[:-lost] + \
        [bytes([9]) + rng.bytes(15) for _ in range(3)]
    replica = Sketch.from_items(replica_state, nbytes=16)
    engine.register(peer, Session(local=replica, pacing=FixedBlock(16)),
                    wire=True)                      # same universal stream

for lost, report in zip(staleness, engine.run()):
    d = lost + 3
    print(f"staleness d={d}: decoded with {report.symbols_used} symbols "
          f"({report.bytes_received} wire bytes, overhead "
          f"{report.overhead(d):.2f}x) from the shared stream")

print(f"peer cache holds {peer.m} symbols — extended once per tick, "
      f"served to {len(staleness)} concurrent sessions in "
      f"{engine.ticks} ticks")
