"""Quickstart: reconcile two sets with Rateless IBLT (paper's core API).

Alice publishes her set as one universal ``SymbolStream``; Bob opens a
``Session`` against it.  The session pulls windows of coded symbols — here
as real wire ``bytes`` (paper §6 encoding) — peels as they arrive, and
stops the moment symbol 0 empties.  Nobody knew d = 42 in advance.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Sketch
from repro.protocol import Session, SymbolStream, run_session

rng = np.random.default_rng(0)

# two parties hold large, mostly-overlapping sets of 32-byte items
common = [bytes([0]) + rng.bytes(31) for _ in range(100_000)]
only_alice = [bytes([1]) + rng.bytes(31) for _ in range(30)]
only_bob = [bytes([2]) + rng.bytes(31) for _ in range(12)]

alice = SymbolStream.from_items(common + only_alice, nbytes=32)
bob = Sketch.from_items(common + only_bob, nbytes=32)

report = run_session(alice, Session(local=bob), wire=True)

d = len(only_alice) + len(only_bob)
print(f"difference size d = {d}")
print(f"coded symbols used = {report.symbols_used}  "
      f"(overhead {report.overhead(d):.2f}x, paper: 1.35-1.72x)")
print(f"wire bytes = {report.bytes_received} "
      f"vs naive {len(common + only_alice) * 32}")
got_a, got_b = report.only_remote_bytes(), report.only_local_bytes()
assert sorted(x.tobytes() for x in got_a) == sorted(only_alice)
assert sorted(x.tobytes() for x in got_b) == sorted(only_bob)
print("recovered symmetric difference exactly. ✓")

# the one-call convenience wrapper (same Session machinery underneath):
from repro.core import reconcile_sets
got_a2, got_b2, m_used = reconcile_sets(Sketch.from_items(
    common + only_alice, nbytes=32), bob)
assert sorted(x.tobytes() for x in got_a2) == sorted(only_alice)
print(f"reconcile_sets agrees (m = {m_used}). ✓")
