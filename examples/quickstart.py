"""Quickstart: reconcile two sets with Rateless IBLT (paper's core API).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Sketch, reconcile_sets

rng = np.random.default_rng(0)

# two parties hold large, mostly-overlapping sets of 32-byte items
common = [bytes([0]) + rng.bytes(31) for _ in range(100_000)]
only_alice = [bytes([1]) + rng.bytes(31) for _ in range(30)]
only_bob = [bytes([2]) + rng.bytes(31) for _ in range(12)]

alice = Sketch.from_items(common + only_alice, nbytes=32)
bob = Sketch.from_items(common + only_bob, nbytes=32)

# Alice streams coded symbols; Bob peels as they arrive and stops the
# stream the moment symbol 0 empties.  Nobody knew d = 42 in advance.
got_a, got_b, m_used = reconcile_sets(alice, bob)

d = len(only_alice) + len(only_bob)
print(f"difference size d = {d}")
print(f"coded symbols used = {m_used}  (overhead {m_used/d:.2f}x, "
      f"paper: 1.35-1.72x)")
print(f"bytes ~= {m_used * (32+8+1)} vs naive {len(common+only_alice)*32}")
assert sorted(x.tobytes() for x in got_a) == sorted(only_alice)
assert sorted(x.tobytes() for x in got_b) == sorted(only_bob)
print("recovered symmetric difference exactly. ✓")
