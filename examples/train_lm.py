"""Train a ~100M-param LM for a few hundred steps on the framework's full
training substrate (sharded step, optimizer, checkpointing).

CPU-friendly default trains a smaller variant; pass --full-100m on real
hardware.  Also demonstrates crash recovery: run with --fail-at N, re-run,
and training resumes from the checkpoint.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="yi-9b")
ap.add_argument("--fail-at", type=int, default=0)
ap.add_argument("--full-100m", action="store_true")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train", "--arch", args.arch,
       "--steps", str(args.steps), "--ckpt-dir", "out/example_ckpt"]
if not args.full_100m:
    cmd += ["--smoke", "--batch", "8", "--seq", "128"]
else:
    cmd += ["--batch", "32", "--seq", "1024"]
if args.fail_at:
    cmd += ["--fail-at", str(args.fail_at)]
print("running:", " ".join(cmd))
sys.exit(subprocess.call(cmd))
