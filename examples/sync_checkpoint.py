"""A stale training replica repairs its checkpoint from a healthy peer via
Rateless IBLT — the paper's Ethereum state-sync scenario mapped onto this
framework's checkpoint store (DESIGN.md §2).

    PYTHONPATH=src python examples/sync_checkpoint.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointStore
from repro.checkpoint.reconcile import PeerEndpoint, sync_from_peer

root = tempfile.mkdtemp()
fresh = CheckpointStore(f"{root}/fresh")
stale = CheckpointStore(f"{root}/stale")

key = jax.random.key(0)
params = {"wte": jax.random.normal(key, (4096, 512)),
          "blocks": [{"w": jax.random.normal(jax.random.fold_in(key, i),
                                             (512, 2048))} for i in range(4)]}
stale.save(100, params)

# peer trained 10 more steps: a small fraction of chunks changed
params["blocks"][2]["w"] = params["blocks"][2]["w"] + 0.01
fresh.save(110, params)

peer = PeerEndpoint(fresh)
report = sync_from_peer(stale, peer)
print(f"symbols used: {report.symbols_used} "
      f"({report.symbol_bytes/1e3:.1f} kB)")
print(f"chunks fetched: {report.chunks_fetched} "
      f"({report.chunk_bytes/1e6:.2f} MB)")
print(f"naive full download: {report.naive_bytes/1e6:.2f} MB")
print(f"savings: {report.savings:.1f}x")
assert stale.manifest()["chunks"] == fresh.manifest()["chunks"]
assert stale.verify() == []
print("replica repaired and verified. ✓")
