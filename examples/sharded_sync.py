"""Sharded fan-out: one 8-shard stream serves replicas over merged payloads.

The server hash-partitions its key space into 8 shards (stable SipHash
shard-of-key — every peer computes the identical partition from the session
key) and keeps one universal symbol cache per shard.  Each replica opens a
``ShardedSession``: every round trip it requests a window for each
still-undecoded shard, the server answers with ONE merged wire payload
(shard-id'd frames), and the replica decodes all touched shards in ONE
batched step.  Hot shards keep growing their windows while settled shards
stop — that's the per-shard ρ(0)=1 termination at work.

    PYTHONPATH=src python examples/sharded_sync.py
"""
import numpy as np

from repro.protocol import FixedBlock, ShardedStream, run_sharded_session

rng = np.random.default_rng(11)
nbytes = 16
state = rng.integers(0, 256, (50_000, nbytes), dtype=np.uint8)

server = ShardedStream.from_items(state, nbytes, n_shards=8)  # encodes ONCE
print(f"server: {server.n_items} items over {server.n_shards} shards "
      f"({', '.join(str(s.n_items) for s in server.shards)})")

for staleness in (24, 400):
    replica_state = np.concatenate(
        [state[:-staleness],
         rng.integers(0, 256, (4, nbytes), dtype=np.uint8)])
    replica = ShardedStream.from_items(replica_state, nbytes, n_shards=8)
    session = server.session(local=replica, pacing=FixedBlock(8))
    report = run_sharded_session(server, session)      # merged wire payloads
    d = staleness + 4
    per_shard = ", ".join(str(sr.symbols_used) for sr in report.shards)
    print(f"replica d={d}: decoded in {report.grow_steps} round trips, "
          f"{report.symbols_used} symbols total [{per_shard}] "
          f"({report.bytes_received} wire bytes, "
          f"overhead {report.overhead(d):.2f}x)")
    assert report.only_remote.shape[0] + report.only_local.shape[0] == d

print(f"server caches hold {server.m} symbols across shards — grown once, "
      f"shared by every replica")
