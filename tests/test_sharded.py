"""Sharded serving: stable partition, merged payloads, shard invariance of
the recovered difference, and the one-batched-device-call decode path."""
import numpy as np
import pytest

from repro.core import Encoder, Sketch
from repro.core.wire import (decode_shard_frames, encode_frames,
                             encode_shard_frames)
from repro.protocol import (FixedBlock, ProtocolError, ShardedReport,
                            ShardedSession, ShardedStream, run_session,
                            run_sharded_session, shard_of)

RNG = np.random.default_rng(2718)


def rand_items(n, nbytes, tag=None):
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if tag is not None:
        out[:, 0] = tag
    return out


def two_sets(n_common, da, db, nbytes):
    common = rand_items(n_common, nbytes, tag=0)
    ai = rand_items(da, nbytes, tag=1)
    bi = rand_items(db, nbytes, tag=2)
    return (np.concatenate([common, ai]), np.concatenate([common, bi]),
            ai, bi)


def as_sorted_bytes(rows):
    return sorted(x.tobytes() for x in rows)


# --------------------------------------------------------- partitioning ----
def test_shard_of_is_a_stable_partition():
    items = rand_items(5000, 16)
    ids = shard_of(items, 8, nbytes=16)
    assert ids.shape == (5000,) and ids.min() >= 0 and ids.max() < 8
    # deterministic, order-independent, and identical for byte/word input
    np.testing.assert_array_equal(ids, shard_of(items, 8, nbytes=16))
    perm = RNG.permutation(5000)
    np.testing.assert_array_equal(ids[perm], shard_of(items[perm], 8,
                                                      nbytes=16))
    from repro.core.hashing import bytes_to_words
    np.testing.assert_array_equal(
        ids, shard_of(bytes_to_words(items, 16), 8, nbytes=16))
    # S=1 degenerates to the unsharded stream
    assert (shard_of(items, 1, nbytes=16) == 0).all()
    # no empty-by-construction shard: every id appears on a 5000-item set
    assert set(np.unique(ids)) == set(range(8))
    # a different session key yields a different partition
    other = shard_of(items, 8, key=(123, 456), nbytes=16)
    assert (ids != other).any()
    with pytest.raises(ValueError):
        shard_of(items, 0, nbytes=16)


def test_sharded_stream_routes_mutations():
    nbytes = 16
    items = rand_items(400, nbytes)
    stream = ShardedStream.from_items(items, nbytes, n_shards=4)
    assert stream.n_items == 400
    ids = shard_of(items, 4, nbytes=nbytes)
    per_shard = [int((ids == s).sum()) for s in range(4)]
    assert [st.n_items for st in stream.shards] == per_shard
    extra = rand_items(40, nbytes, tag=7)
    stream.add_items(extra)
    assert stream.n_items == 440
    stream.remove_items(items[:100])
    assert stream.n_items == 340
    ids2 = shard_of(np.concatenate([items[100:], extra]), 4, nbytes=nbytes)
    assert [st.n_items for st in stream.shards] == \
        [int((ids2 == s).sum()) for s in range(4)]


# --------------------------------------------------------- wire payload ----
def test_shard_frames_roundtrip():
    nbytes = 8
    enc = Encoder(nbytes)
    enc.add_items(rand_items(200, nbytes))
    frames = [(0, encode_frames(enc.window(0, 16), start=0, n_items=200)),
              (3, encode_frames(enc.window(16, 50), start=16, n_items=200))]
    payload = encode_shard_frames(frames, n_shards=4)
    n_shards, out = decode_shard_frames(payload)
    assert n_shards == 4 and len(out) == 2
    sid, sym, n_items, start = out[0]
    assert (sid, n_items, start, sym.m) == (0, 200, 0, 16)
    np.testing.assert_array_equal(sym.sums, enc.window(0, 16).sums)
    sid, sym, n_items, start = out[1]
    assert (sid, n_items, start, sym.m) == (3, 200, 16, 34)
    np.testing.assert_array_equal(sym.counts, enc.window(16, 50).counts)
    # empty payloads are legal (every shard settled)
    assert decode_shard_frames(encode_shard_frames([], 4)) == (4, [])


def test_shard_frames_rejects_garbage():
    nbytes = 8
    frame = encode_frames(Encoder(nbytes).window(0, 4), n_items=0)
    with pytest.raises(ValueError, match="magic"):
        decode_shard_frames(b"XXXX" + b"\x00" * 16)
    with pytest.raises(ValueError, match="truncated"):
        decode_shard_frames(b"")
    with pytest.raises(ValueError, match="truncated"):
        decode_shard_frames(encode_shard_frames([(0, frame)], 2)[:-5])
    with pytest.raises(ValueError, match="shard_id"):
        encode_shard_frames([(2, frame)], 2)
    with pytest.raises(ValueError):
        encode_shard_frames([(0, frame)], 0)
    # shard id beyond the declared partition on the decode side
    bad = bytearray(encode_shard_frames([(1, frame)], 2))
    bad[8:10] = (9).to_bytes(2, "little")      # patch the ext shard_id
    with pytest.raises(ValueError, match="shard_id"):
        decode_shard_frames(bytes(bad))


# ----------------------------------------------------- shard invariance ----
@pytest.mark.parametrize("backend", ["host", "device"])
@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_shard_invariance_property(n_shards, backend):
    """Reconciling a random diff sharded S ∈ {1, 2, 8} ways recovers the
    IDENTICAL symmetric difference, and total coded symbols stay within
    the paper's 1.35–2x overhead band (Fig. 4; d/S ≥ 32 per shard so each
    shard decodes inside the measured regime)."""
    nbytes = 16
    a_items, b_items, ai, bi = two_sets(3000, 320, 80, nbytes)
    d = 400
    stream = ShardedStream.from_items(a_items, nbytes, n_shards=n_shards)
    local = ShardedStream.from_items(b_items, nbytes, n_shards=n_shards)
    session = stream.session(local=local, pacing=FixedBlock(8),
                             backend=backend,
                             max_diff=128 if backend == "device" else None)
    rep = run_session(stream, session, wire=True)   # dispatches on type
    assert isinstance(rep, ShardedReport)
    assert len(rep.shards) == n_shards
    # the union over shards IS the unsharded symmetric difference
    assert as_sorted_bytes(rep.only_remote_bytes()) == as_sorted_bytes(ai)
    assert as_sorted_bytes(rep.only_local_bytes()) == as_sorted_bytes(bi)
    # paper overhead band on TOTAL symbols at decode (2x hard ceiling)
    assert 1.0 <= rep.overhead(d) <= 2.0, \
        f"S={n_shards}: overhead {rep.overhead(d):.2f}"
    assert rep.bytes_received > 0
    assert rep.remote_items == len(a_items)
    # per-shard decode signals: every shard terminated on its own ρ(0)=1
    assert sum(sr.symbols_used for sr in rep.shards) == rep.symbols_used
    assert all(sr.symbols_used >= 1 for sr in rep.shards)


def test_sharded_in_process_equals_wire():
    nbytes = 16
    a_items, b_items, ai, bi = two_sets(800, 40, 10, nbytes)
    mk = lambda: ShardedSession(
        local=ShardedStream.from_items(b_items, nbytes, n_shards=4),
        pacing=FixedBlock(8))
    stream = ShardedStream.from_items(a_items, nbytes, n_shards=4)
    rep_wire = run_sharded_session(stream, mk(), wire=True)
    rep_mem = run_sharded_session(stream, mk(), wire=False)
    assert rep_wire.symbols_used == rep_mem.symbols_used
    assert rep_wire.bytes_received > 0 and rep_mem.bytes_received == 0
    assert as_sorted_bytes(rep_wire.only_remote_bytes()) == \
        as_sorted_bytes(rep_mem.only_remote_bytes()) == as_sorted_bytes(ai)


# ------------------------------------------------- batched device decode ----
def test_device_grow_step_is_one_batched_dispatch(monkeypatch):
    """S=8 device decode issues exactly ONE decode_device_batched call per
    grow step and never falls into the per-shard decode_device path."""
    from repro.kernels import ops
    calls = {"batched": 0, "single": 0}
    real = ops.decode_device_batched
    monkeypatch.setattr(ops, "decode_device_batched",
                        lambda *a, **k: (calls.__setitem__(
                            "batched", calls["batched"] + 1) or real(*a, **k)))
    monkeypatch.setattr(ops, "decode_device",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("per-shard decode_device called")))
    nbytes = 16
    a_items, b_items, ai, bi = two_sets(600, 30, 10, nbytes)
    stream = ShardedStream.from_items(a_items, nbytes, n_shards=8)
    session = stream.session(
        local=ShardedStream.from_items(b_items, nbytes, n_shards=8),
        pacing=FixedBlock(8), backend="device", max_diff=64)
    rep = run_sharded_session(stream, session)
    assert calls["batched"] == rep.grow_steps > 0
    assert as_sorted_bytes(rep.only_remote_bytes()) == as_sorted_bytes(ai)
    assert as_sorted_bytes(rep.only_local_bytes()) == as_sorted_bytes(bi)


def test_decode_device_batched_overflow_is_per_shard():
    """One hot shard tripping max_diff flags ONLY itself; its neighbours
    in the same batched call decode to completion."""
    from repro.kernels.ops import decode_device_batched
    nbytes = 8
    m = 96
    shards = []
    for d in (2, 30):              # cool shard, hot shard
        items = RNG.integers(0, 2**32, size=(300, 2), dtype=np.uint32)
        A, B = Encoder(nbytes), Encoder(nbytes)
        A.add_items(items)
        B.add_items(items[:-d])
        shards.append(A.symbols(m).subtract(B.symbols(m)))
    res = decode_device_batched(shards, nbytes=nbytes, max_diff=8)
    assert res[0].success and not res[0].overflow
    assert res[0].items.shape[0] == 2
    assert res[1].overflow and not res[1].success
    # frozen state: the hot shard recovered nothing it can hand back
    assert res[1].items.shape[0] <= 8


def test_session_overflow_falls_back_per_shard():
    """A tiny per-shard max_diff overflows the device buffers; every shard
    falls back to the exact host peel individually and the reconciliation
    still recovers the exact difference."""
    nbytes = 16
    a_items, b_items, ai, bi = two_sets(500, 36, 6, nbytes)
    stream = ShardedStream.from_items(a_items, nbytes, n_shards=4)
    session = stream.session(
        local=ShardedStream.from_items(b_items, nbytes, n_shards=4),
        pacing=FixedBlock(8), backend="device", max_diff=2)
    rep = run_sharded_session(stream, session)
    assert as_sorted_bytes(rep.only_remote_bytes()) == as_sorted_bytes(ai)
    assert as_sorted_bytes(rep.only_local_bytes()) == as_sorted_bytes(bi)


# ----------------------------------------------------------- protocol ----
def test_sharded_session_protocol_errors():
    nbytes = 16
    items = rand_items(200, nbytes)
    stream = ShardedStream.from_items(items, nbytes, n_shards=4)
    sess = ShardedSession(n_shards=8, nbytes=nbytes)     # wrong partition
    with pytest.raises(ProtocolError, match="partition"):
        sess.offer_payload(stream.payload([(0, 0, 8)]))
    sess = ShardedSession(n_shards=4, nbytes=nbytes)
    with pytest.raises(ProtocolError, match="gap"):
        sess.offer_payload(stream.payload([(1, 8, 16)]))
    # overlap is trimmed, stale windows are no-ops
    sess.offer_payload(stream.payload([(1, 0, 8)]))
    sess.offer_payload(stream.payload([(1, 4, 12), (1, 0, 4)]))
    assert sess._shards[1].decoder.symbols_received == 12
    with pytest.raises(ValueError):
        ShardedSession(nbytes=nbytes)                    # no n_shards
    with pytest.raises(ValueError):
        ShardedSession(local=ShardedStream.from_items(items, nbytes, 4),
                       n_shards=8)                       # mismatched local


def test_offer_windows_validates_before_absorbing():
    """A bad window anywhere in a round rejects the WHOLE round: no shard
    absorbs anything, so a corrected retry is not treated as stale."""
    nbytes = 16
    items = rand_items(300, nbytes)
    stream = ShardedStream.from_items(items, nbytes, n_shards=2)
    sess = ShardedSession(n_shards=2, nbytes=nbytes)
    with pytest.raises(ProtocolError, match="gap"):
        sess.offer_windows([(0, stream.window(0, 0, 16), 0),
                            (1, stream.window(1, 8, 16), 8)])
    assert sess._shards[0].decoder.symbols_received == 0   # nothing absorbed
    with pytest.raises(ProtocolError, match="shard_id"):
        sess.offer_windows([(0, stream.window(0, 0, 16), 0),
                            (5, stream.window(1, 0, 8), 0)])
    assert sess._shards[0].decoder.symbols_received == 0
    # the corrected retry of the same round is consumed in full
    sess.offer_windows([(0, stream.window(0, 0, 16), 0),
                        (1, stream.window(1, 0, 16), 0)])
    assert all(st.decoder.symbols_received == 16 for st in sess._shards)
    # several windows for ONE shard in one round validate against the
    # simulated position, not the stale pre-round one
    sess.offer_windows([(0, stream.window(0, 16, 24), 16),
                        (0, stream.window(0, 24, 32), 24)])
    assert sess._shards[0].decoder.symbols_received == 32


def test_run_sharded_rejects_partition_mismatch():
    """Driving mismatched partitions must raise, not silently
    mis-reconcile (in-process windows carry no n_shards header)."""
    nbytes = 16
    items = rand_items(200, nbytes)
    stream = ShardedStream.from_items(items, nbytes, n_shards=4)
    sess = ShardedSession(
        local=ShardedStream.from_items(items[:-5], nbytes, n_shards=2))
    with pytest.raises(ProtocolError, match="partition"):
        run_sharded_session(stream, sess, wire=False)
    with pytest.raises(ProtocolError, match="partition"):
        run_sharded_session(stream, sess, wire=True)


def test_raw_stream_sharded_decode():
    """local=None recovers the remote shard sets themselves."""
    nbytes = 16
    items = rand_items(48, nbytes)
    stream = ShardedStream.from_items(items, nbytes, n_shards=2)
    sess = ShardedSession(n_shards=2, nbytes=nbytes, pacing=FixedBlock(16))
    rep = run_sharded_session(stream, sess)
    assert as_sorted_bytes(rep.only_remote_bytes()) == as_sorted_bytes(items)
    assert rep.only_local.shape[0] == 0
    assert rep.remote_items == 48


def test_sharded_stream_update_then_sync():
    """Linearity per shard: after add/remove the same sharded stream
    serves correct syncs to a fresh session."""
    nbytes = 16
    state = rand_items(1000, nbytes, tag=0)
    stream = ShardedStream.from_items(state, nbytes, n_shards=4)
    _ = stream.payload([(s, 0, 16) for s in range(4)])   # materialize caches
    new = rand_items(5, nbytes, tag=5)
    stream.add_items(new)
    stream.remove_items(state[:3])
    truth = np.concatenate([state[3:], new])
    local = np.concatenate([truth[:-7], rand_items(2, nbytes, tag=7)])
    sess = stream.session(
        local=ShardedStream.from_items(local, nbytes, n_shards=4),
        pacing=FixedBlock(8))
    rep = run_sharded_session(stream, sess)
    assert as_sorted_bytes(rep.only_remote_bytes()) == \
        as_sorted_bytes(truth[-7:])
    assert as_sorted_bytes(rep.only_local_bytes()) == \
        as_sorted_bytes(local[-2:])
