"""Test-suite bootstrap: fall back to the bundled `hypothesis` shim when
the real package is not installed (see requirements-dev.txt), so the suite
collects and runs in minimal environments."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from _hypothesis_shim import install
    install()
