"""ReconcileEngine: N concurrent peers on one plan/execute loop —
cross-peer batched decode, double-buffered pipeline, overflow pinning."""
import numpy as np
import pytest

from repro.core import Sketch
from repro.protocol import (FixedBlock, ProtocolError, ReconcileEngine,
                            Session, ShardedSession, ShardedStream,
                            SymbolStream, run_session, serve)

RNG = np.random.default_rng(1618)


def rand_items(n, nbytes, tag=None):
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if tag is not None:
        out[:, 0] = tag
    return out


def as_sorted_bytes(rows):
    return sorted(x.tobytes() for x in rows)


def stale_replica(state, lost, added, nbytes):
    """A replica missing the last ``lost`` rows plus ``added`` extras;
    returns (items, remote_only_truth, local_only_truth)."""
    extra = rand_items(added, nbytes, tag=9)
    items = np.concatenate([state[:-lost], extra]) if lost else \
        np.concatenate([state, extra])
    return items, state[-lost:] if lost else state[:0], extra


# ------------------------------------------------- N peers x S shards ----
@pytest.mark.parametrize("n_peers", [1, 3, 8])
@pytest.mark.parametrize("n_shards", [1, 4])
def test_engine_peers_within_overhead_band(n_peers, n_shards):
    """N concurrent peers x S shards on ONE engine: every peer recovers
    its exact difference and stays inside the paper's 1.35-2x overhead
    band (Fig. 4; d large enough for the measured regime)."""
    nbytes = 16
    state = rand_items(1500, nbytes, tag=0)
    lost, added = (40, 8) if n_shards == 1 else (140, 20)
    d = lost + added
    if n_shards == 1:
        stream = SymbolStream.from_items(state, nbytes)
    else:
        stream = ShardedStream.from_items(state, nbytes, n_shards=n_shards)
    engine = ReconcileEngine()
    truths = []
    for _ in range(n_peers):
        items, only_remote, only_local = stale_replica(
            state, lost, added, nbytes)
        if n_shards == 1:
            session = Session(local=Sketch.from_items(items, nbytes),
                              pacing=FixedBlock(8))
        else:
            session = stream.session(
                local=ShardedStream.from_items(items, nbytes,
                                               n_shards=n_shards),
                pacing=FixedBlock(8))
        engine.register(stream, session, wire=True)
        truths.append((only_remote, only_local))
    reports = engine.run()
    assert len(reports) == n_peers
    for rep, (only_remote, only_local) in zip(reports, truths):
        assert as_sorted_bytes(rep.only_remote_bytes()) == \
            as_sorted_bytes(only_remote)
        assert as_sorted_bytes(rep.only_local_bytes()) == \
            as_sorted_bytes(only_local)
        assert 1.0 <= rep.overhead(d) <= 2.0, \
            f"N={n_peers} S={n_shards}: overhead {rep.overhead(d):.2f}"
        assert rep.bytes_received > 0
    assert engine.ticks > 0


# ------------------------------------- one dispatch per shape bucket ----
def test_one_batched_dispatch_per_tick_with_8_peers(monkeypatch):
    """8 concurrent device-backend peers at the same pacing land in ONE
    shape bucket: every engine tick issues exactly one batched device
    dispatch regardless of peer count, and the per-unit decode_device
    path is never taken."""
    from repro.kernels import ops
    calls = {"start": 0}
    real = ops.decode_device_batched_start
    monkeypatch.setattr(
        ops, "decode_device_batched_start",
        lambda *a, **k: (calls.__setitem__("start", calls["start"] + 1)
                         or real(*a, **k)))
    monkeypatch.setattr(ops, "decode_device",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("per-unit decode_device called")))
    nbytes = 16
    state = rand_items(800, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)
    engine = ReconcileEngine()          # pipeline=True -> async dispatches
    for _ in range(8):
        items, *_ = stale_replica(state, 24, 4, nbytes)
        engine.register(stream, Session(local=Sketch.from_items(items, nbytes),
                                        pacing=FixedBlock(8),
                                        backend="device"), wire=True)
    reports = engine.run()
    assert all(r.only_remote.shape[0] == 24 for r in reports)
    # same staleness + same pacing => identical per-tick shapes => exactly
    # one bucket, one batched dispatch per tick, for all 8 peers together
    assert calls["start"] == engine.dispatches == engine.ticks > 0


def test_mixed_progress_buckets_by_shape():
    """Peers at different stream depths split into (few) shape buckets,
    never into per-peer dispatches: dispatches <= buckets-per-tick sum,
    and the engine still recovers every difference."""
    nbytes = 16
    state = rand_items(1200, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)
    engine = ReconcileEngine()
    for lost in (8, 8, 300):            # two cool peers + one deep peer
        items, *_ = stale_replica(state, lost, 2, nbytes)
        engine.register(stream, Session(local=Sketch.from_items(items, nbytes),
                                        pacing=FixedBlock(16),
                                        backend="device"), wire=True)
    reports = engine.run()
    assert [r.only_remote.shape[0] for r in reports] == [8, 8, 300]
    # 3 peers never cost 3 dispatches/tick: equal progress shares a bucket
    assert engine.dispatches < 3 * engine.ticks


# --------------------------------------------------- d=0 termination ----
def test_d0_peer_terminates_immediately_without_stalling_others():
    """An identical replica (d=0) settles on its very first absorb — no
    decode slot, no further requests — while stale peers keep going."""
    nbytes = 16
    state = rand_items(1000, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)
    engine = ReconcileEngine()
    same = Session(local=Sketch.from_items(state.copy(), nbytes),
                   pacing=FixedBlock(8))
    stale = Session(local=Sketch.from_items(state[:-64], nbytes),
                    pacing=FixedBlock(8))
    engine.register(stream, same, wire=True)
    engine.register(stream, stale, wire=True)
    rep_same, rep_stale = engine.run()
    assert rep_same.only_remote.shape[0] == rep_same.only_local.shape[0] == 0
    assert rep_same.symbols_used <= 8          # first window was enough
    assert rep_same.symbols_received <= 8      # ... and it never re-pulled
    assert rep_stale.only_remote.shape[0] == 64
    assert rep_stale.symbols_used > 64         # kept running to completion


# ------------------------------------------------- pipeline semantics ----
@pytest.mark.parametrize("backend", ["host", "device"])
def test_pipeline_matches_serial_symbols_used(backend):
    """Double-buffering absorbs tick t+1 while tick t decodes; the
    termination point is pinned to the decoded prefix, so symbols_used
    (and therefore the reported overhead) matches the serial lockstep
    loop exactly — speculation only ever shows up in symbols_received."""
    nbytes = 16
    state = rand_items(1500, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)
    mk = lambda: Session(local=Sketch.from_items(state[:-48], nbytes),
                         pacing=FixedBlock(8), backend=backend)
    rep_serial = run_session(stream, mk(), wire=True)
    rep_pipe = serve([(stream, mk())], wire=True, pipeline=True)[0]
    assert rep_pipe.symbols_used == rep_serial.symbols_used
    assert rep_pipe.symbols_received >= rep_serial.symbols_received
    assert as_sorted_bytes(rep_pipe.only_remote_bytes()) == \
        as_sorted_bytes(rep_serial.only_remote_bytes())


def test_pipeline_nonconvergence_still_raises():
    """A diverging peer raises through the pipelined loop too (the
    verdict is deferred past the in-flight decode, never dropped)."""
    nbytes = 16
    a = rand_items(40, nbytes, tag=1)
    b = rand_items(40, nbytes, tag=2)
    engine = ReconcileEngine()
    engine.register(SymbolStream.from_items(a, nbytes),
                    Session(local=Sketch.from_items(b, nbytes),
                            pacing=FixedBlock(4), max_m=8), wire=True)
    with pytest.raises(RuntimeError, match="did not converge"):
        engine.run()


# ----------------------------------------------- overflow host pinning ----
def test_overflowed_shards_stay_pinned_to_host(monkeypatch):
    """Satellite fix: once a shard overflows max_diff and falls back to
    the host peel, later grow steps keep it on the host — even across a
    mid-session set_backend("device") — instead of re-dispatching a
    residual already known to exceed the device buffers."""
    from repro.kernels import ops
    nbytes = 16
    state = rand_items(600, nbytes, tag=0)
    stream = ShardedStream.from_items(state, nbytes, n_shards=2)
    session = stream.session(
        local=ShardedStream.from_items(state[:-80], nbytes, n_shards=2),
        pacing=FixedBlock(8), backend="device", max_diff=2)
    # grow until every shard has tripped max_diff (d/S >> 2, so a device
    # decode can never finish a shard — the completing wave overflows)
    for _ in range(64):
        if all(u.pinned_host for u in session._shards):
            break
        reqs = session.requests()
        session.offer_windows([(s, stream.window(s, lo, hi), lo)
                               for s, lo, hi in reqs])
    assert all(u.pinned_host for u in session._shards)
    # mid-session backend churn must not unpin
    session.set_backend("host")
    session.set_backend("device")
    assert all(u.pinned_host for u in session._shards)
    # later rounds: no device dispatch at all — everything is pinned
    monkeypatch.setattr(ops, "decode_device_batched",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("pinned shard re-dispatched")))
    monkeypatch.setattr(ops, "decode_device_batched_start",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("pinned shard re-dispatched")))
    from repro.protocol import run_sharded_session
    rep = run_sharded_session(stream, session)
    assert rep.only_remote.shape[0] == 80
    assert session.grow_steps > 1


# ------------------------------------------------------- registration ----
def test_register_rejects_mismatched_pairs():
    nbytes = 16
    items = rand_items(100, nbytes)
    engine = ReconcileEngine()
    with pytest.raises(ProtocolError, match="partition"):
        engine.register(ShardedStream.from_items(items, nbytes, n_shards=4),
                        ShardedSession(n_shards=2, nbytes=nbytes))
    with pytest.raises(ProtocolError, match="ShardedSession"):
        engine.register(ShardedStream.from_items(items, nbytes, n_shards=4),
                        Session(nbytes=nbytes))


def test_engine_mixes_plain_and_sharded_peers():
    """One engine can serve a plain peer and a sharded peer side by side;
    each reports through its own flavour."""
    nbytes = 16
    state = rand_items(900, nbytes, tag=0)
    plain_stream = SymbolStream.from_items(state, nbytes)
    shard_stream = ShardedStream.from_items(state, nbytes, n_shards=4)
    engine = ReconcileEngine()
    engine.register(plain_stream,
                    Session(local=Sketch.from_items(state[:-40], nbytes),
                            pacing=FixedBlock(8)), wire=True)
    engine.register(shard_stream, shard_stream.session(
        local=ShardedStream.from_items(state[:-70], nbytes, n_shards=4),
        pacing=FixedBlock(8)), wire=True)
    rep_plain, rep_shard = engine.run()
    assert rep_plain.only_remote.shape[0] == 40
    assert rep_shard.only_remote.shape[0] == 70
    assert len(rep_shard.shards) == 4
