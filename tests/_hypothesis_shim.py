"""Minimal stand-in for `hypothesis` when the real package is absent.

Implements exactly what this suite uses — ``@given`` over
``st.integers`` / ``st.floats`` / ``st.sampled_from`` (plus ``.map``) and
``@settings(max_examples=..., deadline=...)`` — with deterministic
per-test sampling (seeded by the test's qualified name) so failures
reproduce.  The first two examples pin the strategy boundaries (all-min,
all-max); the rest are random draws.  Install the real dependency
(``pip install -r requirements-dev.txt``) for true property-based
shrinking and coverage; `tests/conftest.py` only activates this shim as an
import-time fallback.
"""
from __future__ import annotations

import inspect
import random
import sys
import types

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample, lo=None, hi=None):
        self._sample = sample
        self._lo = lo              # boundary examples (None -> sampled)
        self._hi = hi

    def example(self, rng, phase: int):
        if phase == 0 and self._lo is not None:
            return self._lo
        if phase == 1 and self._hi is not None:
            return self._hi
        return self._sample(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._sample(rng)),
                         None if self._lo is None else fn(self._lo),
                         None if self._hi is None else fn(self._hi))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     min_value, max_value)


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     min_value, max_value)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements),
                     elements[0], elements[-1])


def given(*strategies, **kw_strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for phase in range(n):
                args = [s.example(rng, phase) for s in strategies]
                kwargs = {k: s.example(rng, phase)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # empty signature: pytest must not mistake strategy args for fixtures
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    mod.__is_shim__ = True
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
