"""Rateless IBLT encoder/decoder system invariants (paper §3–§4)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CodedSymbols, Encoder, Sketch, StreamDecoder, encode,
                        peel, reconcile, reconcile_sets)

RNG = np.random.default_rng(99)


def rand_items(n, nbytes, tag=None):
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if tag is not None:
        out[:, 0] = tag  # disjointness between groups
    return out


# ---------------------------------------------------------------- encode --
def test_symbol_zero_contains_every_item():
    items = rand_items(50, 16)
    sym = encode(items, 16, 8)
    assert sym.counts[0] == 50  # rho(0) = 1


def test_encode_prefix_consistency():
    """Rateless property: a longer prefix extends, never rewrites (Fig 3)."""
    items = rand_items(64, 24)
    s1 = encode(items, 24, 32)
    s2 = encode(items, 24, 512)
    np.testing.assert_array_equal(s1.sums, s2.sums[:32])
    np.testing.assert_array_equal(s1.checks, s2.checks[:32])
    np.testing.assert_array_equal(s1.counts, s2.counts[:32])


def test_incremental_extension_equals_oneshot():
    items = rand_items(64, 8)
    enc = Encoder(8)
    enc.add_items(items)
    for m in (1, 2, 5, 17, 63, 200):
        enc.extend(m)
    a = enc.symbols(200)
    b = encode(items, 8, 200)
    np.testing.assert_array_equal(a.sums, b.sums)
    np.testing.assert_array_equal(a.checks, b.checks)
    np.testing.assert_array_equal(a.counts, b.counts)


def test_incremental_add_remove_equals_rebuild():
    """Linearity (§4.1): updating the cached symbols in place == re-encoding
    the updated set from scratch."""
    base = rand_items(100, 16, tag=0)
    add = rand_items(10, 16, tag=1)
    rm = base[:7]
    enc = Encoder(16)
    enc.add_items(base)
    _ = enc.symbols(300)          # populate cache first
    enc.add_items(add)            # retro-encoded into the cached prefix
    enc.remove_items(rm)
    target = np.concatenate([base[7:], add])
    fresh = encode(target, 16, 300)
    got = enc.symbols(300)
    np.testing.assert_array_equal(got.sums, fresh.sums)
    np.testing.assert_array_equal(got.checks, fresh.checks)
    np.testing.assert_array_equal(got.counts, fresh.counts)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 40), st.integers(0, 40), st.integers(5, 33))
def test_linearity_subtraction(na, nb, nbytes):
    """IBLT(A) ⊖ IBLT(B) == IBLT(A △ B)  (the enabling identity, §3)."""
    common = rand_items(30, nbytes, tag=0)
    a_only = rand_items(na, nbytes, tag=1)
    b_only = rand_items(nb, nbytes, tag=2)
    m = 64
    sa = encode(np.concatenate([common, a_only]), nbytes, m)
    sb = encode(np.concatenate([common, b_only]), nbytes, m)
    diff = sa.subtract(sb)
    direct_a = encode(a_only, nbytes, m) if na else CodedSymbols.zeros(m, nbytes)
    direct_b = encode(b_only, nbytes, m) if nb else CodedSymbols.zeros(m, nbytes)
    direct = direct_a.subtract(direct_b)
    np.testing.assert_array_equal(diff.sums, direct.sums)
    np.testing.assert_array_equal(diff.checks, direct.checks)
    np.testing.assert_array_equal(diff.counts, direct.counts)


# ----------------------------------------------------------------- decode --
@pytest.mark.parametrize("d", [1, 2, 5, 40, 300])
def test_roundtrip_pure_set(d):
    items = rand_items(d, 16)
    m = max(8, int(2.2 * d))
    res = peel(encode(items, 16, m))
    assert res.success
    got = {r.tobytes() for r in res.items}
    want = {np.ascontiguousarray(w).tobytes()
            for w in encode(items, 16, 1).sums * 0 + 0}  # placeholder
    # compare against original items through the same word packing
    from repro.core import bytes_to_words
    want = {np.ascontiguousarray(w).tobytes() for w in bytes_to_words(items, 16)}
    assert got == want


@pytest.mark.parametrize("da,db", [(0, 5), (5, 0), (13, 7), (50, 50)])
def test_reconcile_directions(da, db):
    common = rand_items(200, 32, tag=0)
    ai = rand_items(da, 32, tag=1)
    bi = rand_items(db, 32, tag=2)
    A = Sketch.from_items(np.concatenate([common, ai]), 32)
    B = Sketch.from_items(np.concatenate([common, bi]), 32)
    only_a, only_b, m_used = reconcile_sets(A, B)
    assert sorted(x.tobytes() for x in only_a) == sorted(x.tobytes() for x in ai)
    assert sorted(x.tobytes() for x in only_b) == sorted(x.tobytes() for x in bi)
    d = da + db
    assert m_used <= max(8, 8 * d)  # sane overhead even with block rounding


def test_identical_sets_decode_immediately():
    items = rand_items(64, 16)
    A = Sketch.from_items(items, 16)
    B = Sketch.from_items(items.copy(), 16)
    only_a, only_b, m_used = reconcile_sets(A, B)
    assert len(only_a) == 0 and len(only_b) == 0
    assert m_used <= 8  # first block: all-zero symbols, symbol 0 empty


def test_undecodable_prefix_reports_failure():
    """With m ≪ d the peeling decoder must stall, not hallucinate."""
    items = rand_items(500, 16)
    res = peel(encode(items, 16, 16))
    assert not res.success
    assert len(res.items) < 500


def test_symbol_zero_decodes_last():
    """ρ(0)=1 ⇒ symbol 0 empties only when everything is recovered — the
    paper's termination signal."""
    items = rand_items(60, 16)
    sym = encode(items, 16, 200)
    res = peel(sym)
    assert res.success
    # prefix that fails: symbol 0 must still be non-empty after peeling
    short = sym.prefix(30)
    res2 = peel(short)
    if not res2.success:
        # re-run manually to inspect the worked buffer
        from repro.core.decoder import _remove_chains  # noqa: F401
        work = short.copy()
        assert not work.is_empty()[0] or res2.success


# ----------------------------------------------------------------- stream --
def test_stream_decoder_matches_batch():
    common = rand_items(300, 16, tag=0)
    ai = rand_items(25, 16, tag=1)
    bi = rand_items(11, 16, tag=2)
    A = Sketch.from_items(np.concatenate([common, ai]), 16)
    B = Sketch.from_items(np.concatenate([common, bi]), 16)
    dec = StreamDecoder(16, local=B)
    m = 0
    while not dec.decoded:
        sym = A.symbols(m + 4)
        batch = CodedSymbols(sym.sums[m:], sym.checks[m:], sym.counts[m:], 16)
        dec.receive(batch)
        m += 4
        assert m < 4096
    only_a, only_b = dec.result()
    assert only_a.shape[0] == 25 and only_b.shape[0] == 11


def test_overhead_band_small_d():
    """Paper Fig. 4: average overhead ≤ ~1.72 at the worst d (≈4), with
    slack for variance at small sample counts."""
    trials, d = 40, 8
    used = []
    for t in range(trials):
        items = rand_items(d, 8)
        enc = Encoder(8)
        enc.add_items(items)
        m = d  # smallest prefix that could possibly decode has m >= d
        while True:
            if peel(enc.symbols(m)).success:
                used.append(m)
                break
            m += 1
    avg = np.mean(used) / d
    assert 1.0 <= avg < 2.3, f"overhead {avg}"


def test_wire_roundtrip():
    from repro.core.wire import decode_stream, encode_stream
    items = rand_items(500, 20)
    sym = encode(items, 20, 128)
    blob = encode_stream(sym)
    back, n = decode_stream(blob)
    assert n == 500
    np.testing.assert_array_equal(back.sums, sym.sums)
    np.testing.assert_array_equal(back.checks, sym.checks)
    np.testing.assert_array_equal(back.counts, sym.counts)
    # §6 claim: count field ~1 byte amortized (we allow <= 2 here)
    per_sym = (len(blob) - 16) / 128 - (20 + 8)
    assert per_sym <= 2.0
