"""Session-oriented protocol layer: windows, pacing, sessions, and the
one-stream-many-peers acceptance scenario (paper §4.1 universality)."""
import numpy as np
import pytest

from repro.core import CodedSymbols, Encoder, Sketch, encode, reconcile_sets
from repro.protocol import (Exponential, FixedBlock, LineRate, ProtocolError,
                            Session, SymbolStream, run_session)

RNG = np.random.default_rng(314)


def rand_items(n, nbytes, tag=None):
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if tag is not None:
        out[:, 0] = tag
    return out


def two_sets(n_common, da, db, nbytes):
    common = rand_items(n_common, nbytes, tag=0)
    ai = rand_items(da, nbytes, tag=1)
    bi = rand_items(db, nbytes, tag=2)
    return (np.concatenate([common, ai]), np.concatenate([common, bi]),
            ai, bi)


# ------------------------------------------------------------ windows ----
def test_window_is_zero_copy_view():
    sym = encode(rand_items(50, 16), 16, 64)
    w = sym.window(8, 40)
    assert w.m == 32 and w.nbytes == 16
    assert w.sums.base is sym.sums and w.checks.base is sym.checks
    np.testing.assert_array_equal(w.counts, sym.counts[8:40])
    # mutations are shared — it is a view, not a copy
    sym.checks[8] ^= np.uint64(1)
    assert w.checks[0] == sym.checks[8]


def test_getitem_slicing():
    sym = encode(rand_items(30, 8), 8, 32)
    np.testing.assert_array_equal(sym[4:12].sums, sym.sums[4:12])
    np.testing.assert_array_equal(sym[:5].counts, sym.prefix(5).counts)
    with pytest.raises(ValueError):
        sym[::2]
    with pytest.raises(TypeError):
        sym[3]
    with pytest.raises(IndexError):
        sym.window(9, 99)


def test_encoder_window_matches_symbols():
    enc = Encoder(16)
    enc.add_items(rand_items(80, 16))
    full = enc.symbols(128)
    win = enc.window(32, 128)
    np.testing.assert_array_equal(win.sums, full.sums[32:])
    np.testing.assert_array_equal(win.counts, full.counts[32:])


# ------------------------------------------------------------- pacing ----
def test_pacing_schedules():
    assert [FixedBlock(5).next_take(m) for m in (0, 5, 80)] == [5, 5, 5]
    # growth=2 reproduces the old reconcile_sets loop: take = max(block, m)
    exp = Exponential(block=8, growth=2.0)
    assert [exp.next_take(m) for m in (0, 8, 16, 100)] == [8, 8, 16, 100]
    # growth=1.5 reproduces the old sync_from_peer loop: max(block, m // 2)
    exp = Exponential(block=16, growth=1.5)
    assert [exp.next_take(m) for m in (0, 16, 64)] == [16, 16, 32]
    # §6 line-rate: one BDP of symbols per pull, regardless of progress
    lr = LineRate(bandwidth=1000, rtt=0.05)
    assert [lr.next_take(m) for m in (0, 1000)] == [50, 50]


# ------------------------------------------------------------ session ----
def test_session_matches_reconcile_sets():
    a_items, b_items, ai, bi = two_sets(500, 13, 7, 32)
    A = Sketch.from_items(a_items, 32)
    B = Sketch.from_items(b_items, 32)
    only_a, only_b, m_used = reconcile_sets(A, B)
    sess = Session(local=Sketch.from_items(b_items, 32),
                   pacing=Exponential(block=8, growth=2.0))
    rep = run_session(SymbolStream(Sketch.from_items(a_items, 32)), sess)
    assert rep.symbols_used == m_used
    assert sorted(x.tobytes() for x in rep.only_remote_bytes()) == \
        sorted(x.tobytes() for x in only_a)
    assert sorted(x.tobytes() for x in rep.only_local_bytes()) == \
        sorted(x.tobytes() for x in only_b)


def test_session_wire_equals_in_process():
    a_items, b_items, ai, bi = two_sets(300, 9, 4, 16)
    stream = SymbolStream.from_items(a_items, 16)
    rep_mem = run_session(stream, Session(local=Sketch.from_items(b_items, 16)))
    rep_wire = run_session(stream, Session(local=Sketch.from_items(b_items, 16)),
                           wire=True)
    assert rep_wire.symbols_used == rep_mem.symbols_used
    assert rep_wire.bytes_received > 0 and rep_mem.bytes_received == 0
    assert rep_wire.remote_items == len(a_items)
    assert sorted(x.tobytes() for x in rep_wire.only_remote_bytes()) == \
        sorted(x.tobytes() for x in rep_mem.only_remote_bytes())


def test_session_rejects_gaps_trims_overlap():
    items = rand_items(50, 16)
    stream = SymbolStream.from_items(items, 16)
    sess = Session(nbytes=16, pacing=FixedBlock(8))
    with pytest.raises(ProtocolError):
        sess.offer(stream.window(8, 16), 8)        # gap: nothing before it
    sess.offer(stream.window(0, 8), 0)
    sess.offer(stream.window(4, 16), 4)            # overlap: head trimmed
    assert sess.symbols_received == 16
    with pytest.raises(ProtocolError):
        sess.offer(encode(rand_items(4, 8), 8, 4), 16)   # wrong geometry ℓ


def test_session_nonconvergence_raises():
    a_items, b_items, *_ = two_sets(10, 5, 5, 16)
    sess = Session(local=Sketch.from_items(b_items, 16),
                   pacing=FixedBlock(4), max_m=8)
    with pytest.raises(RuntimeError, match="did not converge"):
        run_session(SymbolStream.from_items(a_items, 16), sess)


def test_identical_sets_decode_in_first_window():
    items = rand_items(64, 16)
    rep = run_session(SymbolStream.from_items(items, 16),
                      Session(local=Sketch.from_items(items.copy(), 16)))
    assert rep.only_remote.shape[0] == 0 and rep.only_local.shape[0] == 0
    assert rep.symbols_used <= 8


# ------------------------------------- acceptance: one stream, N peers ----
@pytest.mark.parametrize("backend", ["host", "device"])
def test_shared_stream_syncs_three_replicas_over_wire(backend):
    """≥3 replicas of different staleness sync from a SINGLE SymbolStream
    over the bytes-level wire path; every difference is recovered exactly
    and overhead stays within the paper's 1.35–2x band at d ≥ 32.  The
    device backend wave-peels every window through the kernels' decode
    path and must land on the identical protocol trajectory."""
    nbytes = 16
    n_state = 30_000 if backend == "host" else 6_000
    state = rand_items(n_state, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)   # the ONE peer encode

    staleness = (32, 80, 250)     # all d ≥ 32 → inside the measured band
    extra = (3, 5, 2)             # replica-only records (bidirectional diff)
    deepest = 0
    for lost, added in zip(staleness, extra):
        replica_state = np.concatenate(
            [state[:-lost], rand_items(added, nbytes, tag=9)])
        replica = Sketch.from_items(replica_state, nbytes)
        session = Session(local=replica, pacing=FixedBlock(4))
        rep = run_session(stream, session, wire=True, backend=backend)
        assert session.backend == backend
        d = lost + added
        # exact recovery, both directions
        assert sorted(x.tobytes() for x in rep.only_remote_bytes()) == \
            sorted(x.tobytes() for x in state[-lost:])
        assert sorted(x.tobytes() for x in rep.only_local_bytes()) == \
            sorted(x.tobytes() for x in replica_state[-added:])
        # paper overhead band (Fig. 4: 1.35–1.72 mean; 2x hard ceiling here)
        assert 1.0 <= rep.overhead(d) <= 2.0, \
            f"d={d}: overhead {rep.overhead(d):.2f}"
        assert rep.bytes_received > 0 and rep.remote_items == n_state
        deepest = max(deepest, rep.symbols_received)
    # universality: ONE shared cache served everyone — it was extended to
    # exactly the deepest session's reach, never rebuilt per replica
    assert stream.m == deepest


def test_stream_updates_propagate_to_new_sessions():
    """Linearity: after add/remove the SAME stream serves correct syncs."""
    nbytes = 16
    state = rand_items(2000, nbytes, tag=0)
    stream = SymbolStream.from_items(state, nbytes)
    _ = stream.window(0, 64)                      # materialize some cache
    new = rand_items(4, nbytes, tag=5)
    stream.add_items(new)
    stream.remove_items(state[:3])
    truth = np.concatenate([state[3:], new])
    rep = run_session(stream, Session(local=Sketch.from_items(
        np.concatenate([truth[:-6], rand_items(1, nbytes, tag=7)]), nbytes)),
        wire=True)
    assert sorted(x.tobytes() for x in rep.only_remote_bytes()) == \
        sorted(x.tobytes() for x in truth[-6:])
