"""Baseline scheme correctness (they must work to be compared against)."""
import numpy as np
import pytest

from repro.core.baselines.cpi import CPISketch, _to_field
from repro.core.baselines.merkle import MerkleTrieSync
from repro.core.baselines.met_iblt import MetIBLT
from repro.core.baselines.regular_iblt import RegularIBLT, reconcile_regular

RNG = np.random.default_rng(31337)


def items(n, nbytes=16, tag=0):
    out = RNG.integers(0, 2**32, size=(n, (nbytes + 3) // 4), dtype=np.uint32)
    out[:, 0] = (out[:, 0] & 0xFFFFFF00) | tag
    return out


def test_regular_iblt_roundtrip():
    common, ai, bi = items(200, tag=0), items(12, tag=1), items(9, tag=2)
    rec, sides, ok = reconcile_regular(
        np.concatenate([common, ai]), np.concatenate([common, bi]),
        m=128, nbytes=16)
    assert ok
    got_a = {r.tobytes() for r, s in zip(rec, sides) if s > 0}
    assert got_a == {x.tobytes() for x in ai}


def test_regular_iblt_undersized_fails():
    """Theorem A.1: d > m decodes nothing."""
    rec, sides, ok = reconcile_regular(items(500, tag=1), items(1, tag=2),
                                       m=64, nbytes=16)
    assert not ok
    assert len(rec) < 50


def test_met_iblt_roundtrip():
    A = MetIBLT(m0=32, steps=4, nbytes=16)
    B = MetIBLT(m0=32, steps=4, nbytes=16)
    common, ai, bi = items(100, tag=0), items(10, tag=1), items(5, tag=2)
    A.insert(np.concatenate([common, ai]))
    B.insert(np.concatenate([common, bi]))
    # use the full table (largest rate step)
    rec, sides, ok = A.decode(A.table.subtract(B.table))
    assert ok
    got_a = {r.tobytes() for r, s in zip(rec, sides) if s > 0}
    assert got_a == {x.tobytes() for x in ai}


def test_met_iblt_nested_prefix():
    """Rate-compatible: a prefix decodes a small enough difference."""
    A = MetIBLT(m0=64, steps=3, nbytes=16)
    B = MetIBLT(m0=64, steps=3, nbytes=16)
    common, ai = items(100, tag=0), items(4, tag=1)
    A.insert(np.concatenate([common, ai]))
    B.insert(common)
    rec, sides, ok = A.decode(A.prefix(0).subtract(B.prefix(0)))
    assert ok and len(rec) == 4


@pytest.mark.parametrize("da,db", [(3, 2), (8, 0), (10, 10)])
def test_cpi_roundtrip(da, db):
    m = 2 * (da + db) + 2
    A = CPISketch(m, 16)
    B = CPISketch(m, 16)
    common, ai, bi = items(50, tag=0), items(da, tag=1), items(db, tag=2)
    A.insert(np.concatenate([common, ai]))
    B.insert(np.concatenate([common, bi]))
    ra, rb, ok = A.decode_against(B, d_bound=2 * max(da, db, 1))
    assert ok
    want_a = set(_to_field(ai, nbytes=16).tolist()) if da else set()
    want_b = set(_to_field(bi, nbytes=16).tolist()) if db else set()
    assert set(ra) == want_a
    assert set(rb) == want_b


def test_merkle_sync_costs_scale_with_set():
    base = items(2000, nbytes=20, tag=0)
    delta = items(20, nbytes=20, tag=1)
    fresh = MerkleTrieSync(np.concatenate([base, delta]), 20)
    stale = MerkleTrieSync(base, 20)
    by, rounds, leaves = stale.sync_cost(fresh, value_bytes=72)
    assert leaves == 20
    assert rounds >= 3              # lock-step descent
    assert by > 20 * (20 + 72)      # overhead beyond the leaves themselves
    # identical tries: one round, root only
    same = MerkleTrieSync(base, 20)
    by2, rounds2, leaves2 = stale.sync_cost(same, value_bytes=72)
    assert (by2, rounds2, leaves2) == (32, 1, 0)
