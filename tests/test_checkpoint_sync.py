"""Checkpoint store + Rateless-IBLT state repair (the paper's technique as
a first-class framework feature)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointStore
from repro.checkpoint.reconcile import PeerEndpoint, sync_from_peer


def small_tree(key, scale=1.0):
    k = jax.random.key(key)
    return {
        "layer0": {"w": jax.random.normal(k, (256, 300)) * scale,
                   "b": jnp.zeros((300,))},
        "layer1": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                          (300, 128)) * scale},
        "embed": jax.random.normal(jax.random.fold_in(k, 2), (1000, 64)),
    }


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path / "ckpt"))
    tree = small_tree(0)
    store.save(7, tree)
    struct = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    back = store.restore(struct)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.manifest()["step"] == 7
    assert store.verify() == []


def test_verify_detects_corruption(tmp_path):
    store = CheckpointStore(str(tmp_path / "c"))
    store.save(1, small_tree(0))
    cid = next(iter(store.manifest()["chunks"]))
    with open(store._chunk_path(cid), "r+b") as f:
        f.seek(0)
        f.write(b"\xff\xff\xff\xff")
    bad = store.verify()
    assert cid in bad and len(bad) == 1


def test_reconcile_stale_replica(tmp_path):
    """A replica holding an older checkpoint repairs to the latest by
    fetching only the differing chunks, with symbol traffic ~ O(d)."""
    fresh = CheckpointStore(str(tmp_path / "fresh"))
    stale = CheckpointStore(str(tmp_path / "stale"))
    base = small_tree(0)
    stale.save(1, base)
    # the fresh store advanced: one leaf changed entirely, rest identical
    newer = dict(base)
    newer["layer1"] = {"w": np.asarray(base["layer1"]["w"]) + 1.0}
    fresh.save(2, newer)

    peer = PeerEndpoint(fresh)
    report = sync_from_peer(stale, peer)
    assert report.chunks_fetched > 0
    # repaired: manifests identical, all chunks verify
    assert stale.manifest()["chunks"] == fresh.manifest()["chunks"]
    assert stale.verify() == []
    # and the restored tree equals the fresh one
    struct = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          newer)
    got = stale.restore(struct)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(newer)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # communication: far below full re-download
    assert report.total_bytes < report.naive_bytes / 2, report


def test_reconcile_corrupt_chunk(tmp_path):
    """Crash-corrupted chunks are detected by verify() and healed by
    reconciliation (digest mismatch -> manifest divergence -> repair)."""
    a = CheckpointStore(str(tmp_path / "a"))
    b = CheckpointStore(str(tmp_path / "b"))
    tree = small_tree(3)
    a.save(5, tree)
    b.save(5, tree)
    cid = sorted(b.manifest()["chunks"])[1]
    with open(b._chunk_path(cid), "wb") as f:
        f.write(b"garbage")
    # victim recomputes digests of suspect chunks into its manifest
    bad = b.verify()
    assert bad == [cid]
    man = b.manifest()
    import json, os
    from repro.checkpoint.manager import _digest
    name, idx = cid.rsplit("#", 1)
    with open(b._chunk_path(cid), "rb") as f:
        data = np.frombuffer(f.read(), np.uint8)
    man["chunks"][cid] = _digest(name, int(idx), data)
    with open(os.path.join(b.root, "manifest.json"), "w") as f:
        json.dump(man, f)

    report = sync_from_peer(b, PeerEndpoint(a))
    assert report.chunks_fetched == 1
    assert b.verify() == []


def test_peer_incremental_symbol_update(tmp_path):
    """Linearity: after the store changes, the peer updates its cached
    symbol stream in place and new replicas still reconcile correctly."""
    fresh = CheckpointStore(str(tmp_path / "f"))
    tree = small_tree(1)
    fresh.save(1, tree)
    peer = PeerEndpoint(fresh)
    _ = peer.symbols(0, 64)              # warm the universal cache
    old_records = fresh.store_records if hasattr(fresh, "store_records") \
        else fresh.records()
    # store advances
    tree2 = dict(tree)
    tree2["embed"] = np.asarray(tree["embed"]) * 2.0
    fresh.save(2, tree2)
    new_records = fresh.records()
    old_set = {r.tobytes() for r in old_records}
    new_set = {r.tobytes() for r in new_records}
    added = np.array([np.frombuffer(x, np.uint8) for x in new_set - old_set])
    removed = np.array([np.frombuffer(x, np.uint8) for x in old_set - new_set])
    peer.notify_update(added, removed)
    # a stale replica (at step 1) now syncs against the UPDATED cache
    stale = CheckpointStore(str(tmp_path / "s"))
    stale.save(1, tree)
    report = sync_from_peer(stale, peer)
    assert stale.manifest()["chunks"] == fresh.manifest()["chunks"]
    assert stale.verify() == []
