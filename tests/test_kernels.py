"""Pallas kernel validation (interpret=True) against pure-jnp oracles.

Interpret-mode executes the kernel body op-by-op on CPU with ~10 ms/op
overhead, so sweeps use small blocks/K; shape coverage is what matters.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.encoder import Encoder, encode
from repro.core.hashing import DEFAULT_KEY
from repro.core.mapping import indices_matrix_np, kmax, map_seeds
from repro.kernels.iblt_encode import iblt_encode
from repro.kernels.map_indices import map_indices
from repro.kernels.ops import (decode_device, device_symbols_to_host,
                               encode_device, host_symbols_to_device)
from repro.kernels.peel import _purity_body, iblt_apply, purity_scan
from repro.kernels.ref import iblt_apply_ref, iblt_encode_ref, map_indices_ref

RNG = np.random.default_rng(4242)


def rand_items(n, L):
    return RNG.integers(0, 2**32, size=(n, L), dtype=np.uint32)


# -------------------------------------------------------------- mapping --
# NOTE on coverage: XLA-CPU takes minutes to compile the interpret-mode
# wrapper for this kernel once the unrolled SipHash/jump chain crosses
# ~2 message blocks or ~2 grid steps (LLVM chokes on the long sequential
# u32 dependency chain; measured 3m26s for a single extra block — see
# DESIGN.md §3).  Interpret tests therefore pin L=2 (8-byte items — the
# paper's §7.2 benchmark size) and a single grid step; wider L / multi-block
# coverage runs through the identical-math ref path (`map_indices_ref`,
# tested against the host chains at all L in test_core_mapping) and through
# the `slow` marker below.
@pytest.mark.parametrize("K", [4, 6, 8])
def test_map_indices_kernel_vs_ref(K):
    L, block_n = 2, 64
    items = jnp.asarray(rand_items(block_n, L))
    ki, kc = map_indices(items, K=K, m=256, nbytes=4 * L, key=DEFAULT_KEY,
                         block_n=block_n)
    ri, rc = map_indices_ref(items, K=K, m=256, nbytes=4 * L, key=DEFAULT_KEY)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))


@pytest.mark.slow
@pytest.mark.parametrize("L,block_n,K", [(3, 64, 8), (4, 64, 8), (8, 128, 6)])
def test_map_indices_kernel_vs_ref_wide(L, block_n, K):
    items = jnp.asarray(rand_items(block_n * 2, L))
    ki, kc = map_indices(items, K=K, m=256, nbytes=4 * L, key=DEFAULT_KEY,
                         block_n=block_n)
    ri, rc = map_indices_ref(items, K=K, m=256, nbytes=4 * L, key=DEFAULT_KEY)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))


def test_map_indices_kernel_vs_host_chain():
    """Kernel indices == exact host (numpy uint64) chains."""
    L, n, m, K = 2, 64, 64, 8
    items = rand_items(n, L)
    ki, _ = map_indices(jnp.asarray(items), K=K, m=m, nbytes=4 * L,
                        key=DEFAULT_KEY, block_n=64)
    seeds = map_seeds(items, DEFAULT_KEY, 4 * L)
    hm = indices_matrix_np(seeds, m, K=K)
    # host chains saturate at pad=m exactly like the kernel
    np.testing.assert_array_equal(
        np.minimum(np.asarray(ki).astype(np.int64), m), hm)


# --------------------------------------------------------------- encode --
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3).map(lambda e: 64 * e),   # n
       st.sampled_from([1, 2, 4, 8]),             # L words
       st.sampled_from([64, 128, 192]))           # m
def test_iblt_encode_kernel_vs_ref_sweep(n, L, m):
    items = jnp.asarray(rand_items(n, L))
    idxs, chks = map_indices_ref(items, K=10, m=m, nbytes=4 * L,
                                 key=DEFAULT_KEY)
    ks, kc, kn = iblt_encode(items, idxs, chks, m=m, block_m=64, block_n=64)
    rs, rc, rn = iblt_encode_ref(items, idxs, chks, m=m)
    np.testing.assert_array_equal(np.asarray(ks)[:m], np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kc)[:m], np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(kn)[:m], np.asarray(rn))


def test_iblt_encode_grid_accumulation():
    """Multi-block grids (m and n both tiled) accumulate correctly."""
    n, L, m = 256, 2, 256
    items = jnp.asarray(rand_items(n, L))
    idxs, chks = map_indices_ref(items, K=12, m=m, nbytes=8, key=DEFAULT_KEY)
    ks, kc, kn = iblt_encode(items, idxs, chks, m=m, block_m=64, block_n=64)
    rs, rc, rn = iblt_encode_ref(items, idxs, chks, m=m)
    np.testing.assert_array_equal(np.asarray(ks)[:m], np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kn)[:m], np.asarray(rn))


def test_encode_device_equals_host_encoder():
    """Full device pipeline == host incremental encoder, bit for bit.
    (K = kmax(m): exact chains never truncate at this size.)"""
    n, L, m = 300, 4, 128
    items = rand_items(n, L)
    s, c, cnt = encode_device(jnp.asarray(items), m=m, nbytes=16,
                              block_n=128, block_m=128)
    dev = device_symbols_to_host(s, c, cnt, 16)
    host = encode(items, 16, m)
    np.testing.assert_array_equal(dev.sums, host.sums)
    np.testing.assert_array_equal(dev.checks, host.checks)
    np.testing.assert_array_equal(dev.counts, host.counts)


def test_encode_device_decodes():
    """Device-encoded symbols feed the host peeling decoder."""
    from repro.core import peel
    items = rand_items(40, 4)
    s, c, cnt = encode_device(jnp.asarray(items), m=128, nbytes=16,
                              block_n=64, block_m=64)
    res = peel(device_symbols_to_host(s, c, cnt, 16))
    assert res.success
    got = {r.tobytes() for r in res.items}
    assert got == {i.tobytes() for i in items}


def test_encode_device_ragged_n_padding():
    """n not a multiple of block_n: zero-padding must not leak."""
    items = rand_items(100, 2)
    s1, c1, n1 = encode_device(jnp.asarray(items), m=64, nbytes=8,
                               block_n=64, block_m=64)
    host = encode(items, 8, 64)
    dev = device_symbols_to_host(s1, c1, n1, 8)
    np.testing.assert_array_equal(dev.sums, host.sums)
    np.testing.assert_array_equal(dev.counts, host.counts)


@pytest.mark.parametrize("mapping", ["ref", "pallas"])
def test_encode_device_padded_equals_unpadded(mapping):
    """Regression: the same items encoded through a block size that needs
    zero-padding and one that doesn't produce bit-identical symbols.
    (K is truncated identically on both runs, so bit-equality holds even
    at a small K that keeps the interpret-mode kernel cheap.)"""
    items = jnp.asarray(rand_items(96, 2))
    kw = dict(m=64, nbytes=8, K=8, block_m=64, mapping=mapping)
    s_pad, c_pad, n_pad = encode_device(items, block_n=64, **kw)   # 96 -> 128
    s_raw, c_raw, n_raw = encode_device(items, block_n=32, **kw)   # no pad
    np.testing.assert_array_equal(np.asarray(s_pad), np.asarray(s_raw))
    np.testing.assert_array_equal(np.asarray(c_pad), np.asarray(c_raw))
    np.testing.assert_array_equal(np.asarray(n_pad), np.asarray(n_raw))


# ----------------------------------------------------------------- peel --
def _small_diff(d, L, m, seed=5):
    rng = np.random.default_rng(seed)
    pool = rng.integers(0, 2**32, size=(30 + d, L), dtype=np.uint32)
    pool[:, 0] = np.arange(pool.shape[0])
    A, B = Encoder(4 * L), Encoder(4 * L)
    A.add_items(pool)
    B.add_items(pool[:30])
    return A.symbols(m).subtract(B.symbols(m))


def test_purity_scan_kernel_vs_ref():
    """Pallas purity kernel == pure-jnp purity over a real difference
    (mix of pure, empty, and multi-item symbols, both signs)."""
    sym = _small_diff(6, 2, 64)
    sym.counts[:8] *= -1          # exercise negative sides too
    sums, checks, counts = host_symbols_to_device(sym)
    counts = counts[:, None]
    kern = purity_scan(sums, checks, counts, key=DEFAULT_KEY, nbytes=8,
                       block_m=64)
    ref = _purity_body(sums, checks, counts, key=DEFAULT_KEY, nbytes=8)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))
    assert int(np.sum(np.asarray(ref) != 0)) > 0   # scenario has pure rows


def test_iblt_apply_kernel_vs_ref():
    """Signed-removal kernel == bit-parity oracle, mixed ±1/0 sides."""
    n, L, m, K = 64, 2, 64, 10
    items = jnp.asarray(rand_items(n, L))
    idxs, chks = map_indices_ref(items, K=K, m=m, nbytes=8, key=DEFAULT_KEY)
    sides = jnp.asarray(RNG.integers(-1, 2, size=n, dtype=np.int32))
    idxs = jnp.where(sides[:, None] != 0, idxs, jnp.int32(m))
    ks, kc, kn = iblt_apply(items, idxs, chks, sides, m=m, block_m=64,
                            block_n=64)
    rs, rc, rn = iblt_apply_ref(items, idxs, chks, sides, m=m, m_out=64)
    np.testing.assert_array_equal(np.asarray(ks)[:m], np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(kc)[:m], np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(kn)[:m], np.asarray(rn))


def test_decode_device_pallas_engine_equals_ref_engine():
    """Full wave loop through the Pallas kernels == the jnp ref engine,
    wave for wave (same K so chain truncation is identical)."""
    sym = _small_diff(3, 2, 64)
    dev = host_symbols_to_device(sym)
    kw = dict(nbytes=8, K=14, block_n=64, block_m=64)
    rp = decode_device(*dev, kernel="pallas", **kw)
    rr = decode_device(*dev, kernel="ref", **kw)
    assert rp.success == rr.success and rp.rounds == rr.rounds
    np.testing.assert_array_equal(rp.items, rr.items)
    np.testing.assert_array_equal(rp.sides, rr.sides)
    np.testing.assert_array_equal(rp.residual.sums, rr.residual.sums)
    np.testing.assert_array_equal(rp.residual.checks, rr.residual.checks)
    np.testing.assert_array_equal(rp.residual.counts, rr.residual.counts)
