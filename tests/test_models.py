"""Per-architecture smoke tests (reduced same-family configs, CPU) and
numerical consistency of the sequence-parallel forms vs step recurrences."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model
from repro.train.loop import init_train_state, make_opt_config, make_train_step

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def make_batch(cfg, B=2, S=32, key=0):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                     jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.zeros((B, cfg.encoder_frames, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, mesh):
    """One forward + one optimizer step on a reduced config: finite loss,
    correct logits shape, params updated, still finite after the step."""
    cfg = smoke_config(arch)
    model = build_model(cfg, mesh)
    opt_cfg = make_opt_config(cfg, total_steps=10)
    params, opt_state, _ = init_train_state(model, opt_cfg, jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    step = make_train_step(model, opt_cfg)
    p2, o2, m2 = step(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert int(o2["step"]) == 1
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0
    # loss decreases over a few steps on a repeated batch (sanity, lenient)
    p, o = p2, o2
    first = float(m2["loss"])
    for _ in range(3):
        p, o, m = step(p, o, batch)
    assert float(m["loss"]) < first * 1.5


@pytest.mark.parametrize("arch", ["yi-9b", "rwkv6-1.6b", "recurrentgemma-2b",
                                  "whisper-base"])
def test_decode_matches_forward(arch, mesh):
    """Teacher-forced decode logits == full-forward logits per position."""
    cfg = smoke_config(arch)
    model = build_model(cfg, mesh)
    params, _ = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S, key=3)
    toks = batch["tokens"]
    if cfg.family == "encdec":
        full_logits, _ = _encdec_full(model, params, batch)
    else:
        x = model._embed_inputs(params, {"tokens": toks})
        h, _, _ = model._stack(params, x)
        full_logits = model.logits(params, h)
    cache_struct, _ = model.cache_spec(B, S)
    caches = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype), cache_struct,
                          is_leaf=lambda t: hasattr(t, "shape") and
                          not isinstance(t, jnp.ndarray))
    if cfg.family == "encdec":
        enc_out = model.encode(params, batch["frames"])
        from repro.models.attention import encode_kv
        # fill cross K/V into the cache (serving engine does this at prefill)
        xks, xvs = [], []
        dec = params["dec"]
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree.map(lambda a: a[i], dec)
            k_, v_ = encode_kv(enc_out, lp["cross"], cfg)
            xks.append(k_)
            xvs.append(v_)
        caches = dict(caches)
        caches["xk"] = jnp.stack(xks)
        caches["xv"] = jnp.stack(xvs)
    errs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-4, (arch, max(errs))


def _encdec_full(model, params, batch):
    enc_out = model.encode(params, batch["frames"])
    from repro.models.layers import embed_lookup, unembed, rmsnorm
    x = embed_lookup(params["embed"], batch["tokens"])
    x, caches = model._dec_stack(params, x, enc_out)
    return unembed(x, params["embed"]), caches


def test_moe_ep_equals_tp_without_drops(mesh):
    import dataclasses
    cfg = smoke_config("qwen3-moe-30b-a3b")
    ep = build_model(dataclasses.replace(cfg, capacity_factor=8.0), mesh)
    tp = build_model(dataclasses.replace(cfg, moe_mode="tp"), mesh)
    params, _ = ep.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 16, key=5)
    x = ep._embed_inputs(params, {"tokens": batch["tokens"]})
    h1, _, a1 = ep._stack(params, x)
    h2, _, a2 = tp._stack(params, x)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_capacity_drops_tokens(mesh):
    """Low capacity must change outputs (token dropping is real)."""
    import dataclasses
    cfg = smoke_config("qwen3-moe-30b-a3b")
    lo = build_model(dataclasses.replace(cfg, capacity_factor=0.25), mesh)
    hi = build_model(dataclasses.replace(cfg, capacity_factor=8.0), mesh)
    params, _ = lo.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 32, key=6)
    x = lo._embed_inputs(params, {"tokens": batch["tokens"]})
    h1, _, _ = lo._stack(params, x)
    h2, _, _ = hi._stack(params, x)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4


def test_rwkv_chunked_equals_naive_scan(mesh):
    """Chunkwise-parallel WKV == naive per-step recurrence."""
    from repro.models.rwkv6 import wkv_chunked, wkv_step
    B, S, H, dh = 2, 128, 2, 8
    k = jax.random.key(7)
    ks = jax.random.split(k, 5)
    r, kk, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)) * 0.5 - 0.5)
    u = jax.random.normal(ks[4], (H, dh)) * 0.5
    state0 = jnp.zeros((B, H, dh, dh))
    o_fast, s_fast = wkv_chunked(r, kk, v, logw, u, state0)
    o_ref = []
    s = state0
    for t in range(S):
        o_t, s = wkv_step(r[:, t], kk[:, t], v[:, t], logw[:, t], u, s)
        o_ref.append(o_t)
    o_ref = jnp.stack(o_ref, axis=1)
    np.testing.assert_allclose(np.asarray(o_fast), np.asarray(o_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s),
                               atol=1e-3, rtol=1e-3)


def test_local_attention_window(mesh):
    """A token > window away must not influence the output."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config("recurrentgemma-2b"),
                              block_pattern=("local",), n_layers=1, window=4)
    model = build_model(cfg, mesh)
    params, _ = model.init(jax.random.key(0))
    B, S = 1, 12
    t1 = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)  # perturb far-past token
    outs = []
    for tk in (t1, t2):
        x = model._embed_inputs(params, {"tokens": tk})
        h, _, _ = model._stack(params, x)
        outs.append(model.logits(params, h))
    # last position attends only to the last `window` tokens
    np.testing.assert_allclose(np.asarray(outs[0][:, -1]),
                               np.asarray(outs[1][:, -1]), atol=1e-5)
    assert float(jnp.max(jnp.abs(outs[0][:, 0] - outs[1][:, 0]))) > 1e-4


def test_all_full_configs_construct():
    """The real (non-reduced) configs are well-formed (no allocation)."""
    for a in ARCHS:
        cfg = get_config(a)
        assert cfg.d_model % cfg.n_heads == 0 or cfg.d_head
        assert cfg.head_dim % 16 == 0  # KV-cache dh sharding assumption
        if cfg.n_experts:
            assert cfg.n_experts % 16 == 0
        pat = cfg.pattern()
        assert len(pat) == cfg.n_layers
