"""Wire codec (paper §6): vectorized frame/stream codecs, nonzero-start
windows, raw-stream decoding, loop/vectorized equivalence."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CodedSymbols, Encoder, StreamDecoder, encode
from repro.core.hashing import bytes_to_words
from repro.core.wire import (decode_frames, decode_frames_loop, decode_stream,
                             encode_frames, encode_frames_loop, encode_stream,
                             varint_count_bytes)

RNG = np.random.default_rng(2718)


def rand_items(n, nbytes, tag=None):
    out = RNG.integers(0, 256, size=(n, nbytes), dtype=np.uint8)
    if tag is not None:
        out[:, 0] = tag
    return out


def assert_symbols_equal(a: CodedSymbols, b: CodedSymbols):
    np.testing.assert_array_equal(a.sums, b.sums)
    np.testing.assert_array_equal(a.checks, b.checks)
    np.testing.assert_array_equal(a.counts, b.counts)


# ------------------------------------------------------------- frames ----
def test_frame_roundtrip_start_zero():
    sym = encode(rand_items(400, 20), 20, 128)
    blob = encode_frames(sym)
    back, n, start = decode_frames(blob)
    assert (n, start) == (400, 0)
    assert_symbols_equal(back, sym)


def test_frame_roundtrip_nonzero_start():
    """A mid-stream window is self-describing: the receiver reconstructs
    counts from the (n_items, start) carried in the frame header."""
    sym = encode(rand_items(1000, 16), 16, 256)
    for lo, hi in ((1, 2), (7, 64), (100, 256)):
        blob = encode_frames(sym.window(lo, hi), start=lo, n_items=1000)
        back, n, start = decode_frames(blob)
        assert (n, start) == (1000, lo)
        assert_symbols_equal(back, sym.window(lo, hi))


def test_frame_loop_and_vectorized_are_byte_identical():
    sym = encode(rand_items(300, 13), 13, 200)   # ℓ=13: word-padding case
    win = sym.window(32, 200)
    assert encode_frames(win, 32, 300) == encode_frames_loop(win, 32, 300)
    a, na, sa = decode_frames(encode_frames(win, 32, 300))
    b, nb, sb = decode_frames_loop(encode_frames(win, 32, 300))
    assert (na, sa) == (nb, sb) == (300, 32)
    assert_symbols_equal(a, b)


def test_frame_negative_counts_difference_stream():
    """Zig-zag path: a difference stream has negative counts."""
    common = rand_items(200, 16, tag=0)
    sa = encode(np.concatenate([common, rand_items(5, 16, tag=1)]), 16, 64)
    sb = encode(np.concatenate([common, rand_items(30, 16, tag=2)]), 16, 64)
    diff = sa.subtract(sb)
    assert (diff.counts < 0).any()
    back, _, _ = decode_frames(encode_frames(diff, 0, 205))
    assert_symbols_equal(back, diff)


# ------------------------------------------------- legacy stream codec ----
def test_decode_stream_nonzero_start():
    """The decode_stream(data, start != 0) path: expected-count baseline
    must follow the window offset."""
    n = 5000
    sym = encode(rand_items(n, 24), 24, 512)
    for lo in (1, 33, 400):
        blob = encode_stream(sym.window(lo, 512), start=lo, n_items=n)
        back, got_n = decode_stream(blob, start=lo)
        assert got_n == n
        assert_symbols_equal(back, sym.window(lo, 512))
        # decoding with the wrong start mis-reconstructs the counts
        wrong, _ = decode_stream(blob, start=0)
        assert not np.array_equal(wrong.counts, sym.counts[lo:])


def test_stream_decoder_raw_stream():
    """StreamDecoder(local=None) recovers the full set from its own wire
    stream (no local subtraction — counts all +1)."""
    items = rand_items(40, 16)
    enc = Encoder(16)
    enc.add_items(items)
    dec = StreamDecoder(16, local=None)
    m, step = 0, 16
    while not dec.decoded:
        blob = encode_frames(enc.window(m, m + step), start=m, n_items=40)
        sym, _, start = decode_frames(blob)
        assert start == m
        dec.receive(sym)
        m += step
        assert m < 4096
    got, other = dec.result()
    assert other.shape[0] == 0
    want = bytes_to_words(items, 16)
    assert sorted(r.tobytes() for r in got) == sorted(r.tobytes() for r in want)


# ----------------------------------------------------- property tests ----
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 120), st.integers(4, 33), st.integers(0, 50))
def test_frame_roundtrip_property(m, nbytes, start):
    """decode(encode(sym)) == sym for random geometry, including the varint
    count deltas at arbitrary window offsets."""
    n = RNG.integers(1, 500)
    enc = Encoder(nbytes)
    enc.add_items(rand_items(int(n), nbytes))
    win = enc.window(start, start + m)
    back, got_n, got_start = decode_frames(
        encode_frames(win, start=start, n_items=int(n)))
    assert (got_n, got_start) == (n, start)
    assert_symbols_equal(back, win)


def test_empty_window_frame_roundtrip():
    empty = CodedSymbols.zeros(0, 16)
    back, n, start = decode_frames(encode_frames(empty, start=7, n_items=9))
    assert (back.m, n, start) == (0, 9, 7)
    back2, n2 = decode_stream(encode_stream(empty))
    assert (back2.m, n2) == (0, 0)


def test_nonzero_start_requires_n_items():
    sym = encode(rand_items(10, 16), 16, 32)
    with pytest.raises(ValueError, match="n_items"):
        encode_frames(sym.window(4, 32), start=4)


def test_varint_count_bytes_matches_encoding():
    """wire_bytes() accounting equals the actual encoded size."""
    n = 3000
    sym = encode(rand_items(n, 16), 16, 256)
    blob = encode_frames(sym)
    body_counts = len(blob) - 24 - 256 * (16 + 8)
    assert body_counts == varint_count_bytes(sym.counts, n, 0)
    # §6 claim: ~1 byte amortized per symbol
    assert body_counts / 256 <= 2.0
