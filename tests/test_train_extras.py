"""Training substrate extras: gradient compression, microbatching
equivalence, optimizer variants, crash-recovery driver."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.compression import ErrorFeedbackInt8, _dequantize, _quantize


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bounded(seed, scale):
    x = jax.random.normal(jax.random.key(seed), (64,)) * scale
    q, s = _quantize(x)
    err = jnp.max(jnp.abs(_dequantize(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_is_unbiased_over_steps():
    """Σ transmitted == Σ true gradients up to the final residual — the
    error-feedback invariant that preserves convergence."""
    comp = ErrorFeedbackInt8()
    key = jax.random.key(0)
    state = {"ef": None}
    total_true = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    opt_state = {}
    for t in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (32,))}
        sent, opt_state = comp.apply(g, opt_state)
        total_true += g["w"]
        total_sent += sent["w"]
    resid = opt_state["ef"]["w"]
    np.testing.assert_allclose(np.asarray(total_sent + resid),
                               np.asarray(total_true), rtol=1e-4, atol=1e-4)


def test_compression_in_train_step_still_learns():
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.train.loop import (init_train_state, make_opt_config,
                                  make_train_step)
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    cfg = smoke_config("starcoder2-3b")
    model = build_model(cfg, mesh)
    opt_cfg = make_opt_config(cfg, total_steps=10)
    params, opt_state, _ = init_train_state(model, opt_cfg, jax.random.key(0))
    step = make_train_step(model, opt_cfg, compression=ErrorFeedbackInt8())
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    losses = []
    for _ in range(4):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # memorizing a constant batch
    comp_b, raw_b = ErrorFeedbackInt8.wire_bytes(
        jax.tree.map(lambda p: p, params))
    assert comp_b * 3 < raw_b  # ~4x for fp32, 8x for future bf16 wires


def test_microbatch_accumulation_matches_single():
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.train.loop import (init_train_state, make_opt_config,
                                  make_train_step)
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    cfg = smoke_config("qwen3-4b")
    model = build_model(cfg, mesh)
    opt_cfg = make_opt_config(cfg, total_steps=10)
    params, opt_state, _ = init_train_state(model, opt_cfg, jax.random.key(1))
    batch = {"tokens": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.key(3), (4, 32), 0,
                                          cfg.vocab)}
    p1, _, m1 = make_train_step(model, opt_cfg)(params, opt_state, batch)
    p2, _, m2 = make_train_step(model, opt_cfg, microbatches=2)(
        params, opt_state, batch)
    # same data -> same accumulated gradient -> same update (fp32 accum)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)


def test_crash_recovery_driver(tmp_path):
    """launch.train: crash at step 6, restart resumes from the checkpoint
    and finishes — the fleet fault-tolerance path end to end."""
    env = dict(os.environ, PYTHONPATH="src")
    ckpt = str(tmp_path / "ckpt")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "starcoder2-3b", "--smoke", "--steps", "10", "--batch", "2",
            "--seq", "32", "--ckpt-every", "5", "--ckpt-dir", ckpt]
    r1 = subprocess.run(base + ["--fail-at", "6"], env=env, cwd="/root/repo",
                        capture_output=True, text=True, timeout=600)
    assert r1.returncode == 17, r1.stderr[-500:]
    assert "[ckpt] step 5" in r1.stdout
    r2 = subprocess.run(base, env=env, cwd="/root/repo",
                        capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-500:]
    assert "resumed from step 5" in r2.stdout
    assert "done" in r2.stdout
