"""Mapping + hashing invariants (paper §4.1–4.2)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import siphash24, siphash24_pair, rho, kmax
from repro.core.mapping import (indices_matrix_j, indices_matrix_np,
                                item_indices_np, map_seeds, map_seeds_pair)

RNG = np.random.default_rng(1234)


def rand_words(n, L):
    return RNG.integers(0, 2**32, size=(n, L), dtype=np.uint32)


# ---------------------------------------------------------------- hashing --
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 64),
       st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1))
def test_siphash_host_device_bitexact(L, n, k0, k1):
    w = rand_words(n, L)
    h = siphash24(w, (k0, k1), nbytes=4 * L)
    hi, lo = siphash24_pair(jnp.asarray(w), (k0, k1), nbytes=4 * L)
    h2 = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(lo).astype(np.uint64)
    np.testing.assert_array_equal(h, h2)


def test_siphash_rfc_vector():
    """RFC/reference test vector: key 000102..0f, msg 000102..07."""
    key = (0x0706050403020100, 0x0F0E0D0C0B0A0908)
    words = np.array([[0x03020100, 0x07060504]], dtype=np.uint32)
    got = siphash24(words, key, nbytes=8)[0]
    assert got == np.uint64(0x93F5F5799A932462)


def test_siphash_keyed():
    w = rand_words(8, 4)
    assert not np.array_equal(siphash24(w, (1, 2)), siphash24(w, (1, 3)))


# ---------------------------------------------------------------- mapping --
def test_first_index_always_zero():
    seeds = map_seeds(rand_words(200, 8), (1, 2), 32)
    M = indices_matrix_np(seeds, 1 << 14)
    assert np.all(M[:, 0] == 0)  # rho(0) = 1


def test_chain_strictly_monotone():
    seeds = map_seeds(rand_words(100, 8), (7, 9), 32)
    M = indices_matrix_np(seeds, 4096)
    for row in M:
        live = row[row < 4096]
        assert np.all(np.diff(live) >= 1)


def test_mapping_probability_matches_rho():
    """Empirical inclusion probability tracks ρ(i) (within the paper's
    stated C⁻¹ approximation, which shifts small-i mass by ~4%)."""
    n = 40_000
    seeds = map_seeds(rand_words(n, 8), (3, 5), 32)
    m = 256
    M = indices_matrix_np(seeds, m)
    counts = np.bincount(M[M < m].ravel(), minlength=m) / n
    i = np.array([2, 4, 8, 16, 32, 64, 128])
    emp, theo = counts[i], rho(i)
    assert np.all(np.abs(emp - theo) / theo < 0.08)


def test_host_device_chains_identical():
    n, m = 512, 2048
    w = rand_words(n, 8)
    seeds = map_seeds(w, (11, 13), 32)
    Mh = indices_matrix_np(seeds, m)
    hi, lo = map_seeds_pair(jnp.asarray(w), (11, 13), 32)
    Md = np.asarray(indices_matrix_j(hi, lo, m, K=Mh.shape[1]))
    np.testing.assert_array_equal(Mh, Md.astype(np.int64))


def test_kmax_bounds_chain_length():
    """No item maps more than kmax(m) times within m (statistical)."""
    for m in (64, 1024, 1 << 16):
        seeds = map_seeds(rand_words(20_000, 4), (17, 19), 16)
        M = indices_matrix_np(seeds, m)  # K defaults to kmax(m)
        # last column must already be saturated (= m) for every item,
        # i.e. kmax was large enough to exhaust every chain.
        assert np.all(M[:, -1] == m), f"kmax({m}) too small"


def test_expected_density_is_logarithmic():
    """Each item maps to ~2·ln(m/2) of the first m symbols (§4.1.2)."""
    n, m = 5_000, 8192
    seeds = map_seeds(rand_words(n, 4), (23, 29), 16)
    M = indices_matrix_np(seeds, m)
    mean_deg = (M < m).sum() / n
    from repro.core import expected_degree
    assert abs(mean_deg - expected_degree(m)) / expected_degree(m) < 0.05


def test_universality_prefix_consistency():
    """Symbols for index i do not depend on how many symbols were asked
    for — the defining rateless property."""
    seeds = map_seeds(rand_words(64, 4), (31, 37), 16)
    M1 = indices_matrix_np(seeds, 128)
    M2 = indices_matrix_np(seeds, 4096)
    for r1, r2 in zip(M1, M2):
        a = r1[r1 < 128]
        b = r2[r2 < 128]
        np.testing.assert_array_equal(a, b)
