"""Device wave-peeling decoder ≡ host peel (items, sides, success).

These run the decoder's pure-jnp "ref" engine (the CPU path of
``decode_device``); the Pallas kernels behind the same wave algebra are
validated in tests/test_kernels.py at interpret-friendly sizes.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Encoder, peel
from repro.core.decoder import resolve_backend
from repro.core.hashing import DEFAULT_KEY
from repro.core.stream import StreamDecoder
from repro.core.symbols import CodedSymbols
from repro.kernels.ops import (decode_device, device_symbols_to_host,
                               host_symbols_to_device)

RNG = np.random.default_rng(2025)


def diff_symbols(d_a, d_b, L, m, n_common=40, rng=RNG):
    """Difference symbols of two sets with |A\\B| = d_a, |B\\A| = d_b."""
    nbytes = 4 * L
    pool = rng.integers(0, 2**32, size=(n_common + d_a + d_b, L),
                        dtype=np.uint32)
    pool[:, 0] = np.arange(pool.shape[0])   # force distinct items
    common, ai, bi = np.split(pool, [n_common, n_common + d_a])
    A, B = Encoder(nbytes), Encoder(nbytes)
    A.add_items(np.concatenate([common, ai]))
    B.add_items(np.concatenate([common, bi]))
    return A.symbols(m).subtract(B.symbols(m)), ai, bi


def as_sets(items, sides):
    return {(r.tobytes(), int(s)) for r, s in zip(items, sides)}


# ------------------------------------------------- host ≡ device sweep ----
@pytest.mark.parametrize("L", [1, 2, 8])
@pytest.mark.parametrize("d", [0, 1, 37, 500])
def test_decode_device_equals_host_peel(d, L):
    d_a = d // 2
    d_b = d - d_a
    m = max(16, int(2.2 * d))
    sym, _, _ = diff_symbols(d_a, d_b, L, m)
    host = peel(sym)
    res = decode_device(*host_symbols_to_device(sym), nbytes=4 * L)
    assert not res.overflow
    assert res.success == host.success
    assert as_sets(res.items, res.sides) == as_sets(host.items, host.sides)
    if host.success:
        assert res.residual.is_empty().all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 48), st.sampled_from([1, 2, 4]),
       st.floats(1.6, 3.0), st.integers(0, 2**31 - 1))
def test_decode_device_equals_host_peel_random(d, L, factor, seed):
    """Random cases, including under-provisioned prefixes (decode fails on
    both paths identically)."""
    rng = np.random.default_rng(seed)
    d_a = int(rng.integers(0, d + 1))
    m = max(8, int(factor * d))
    sym, _, _ = diff_symbols(d_a, d - d_a, L, m, n_common=20, rng=rng)
    host = peel(sym)
    res = decode_device(*host_symbols_to_device(sym), nbytes=4 * L)
    assert not res.overflow
    assert res.success == host.success
    assert as_sets(res.items, res.sides) == as_sets(host.items, host.sides)


def test_decode_device_empty_prefix():
    sym = CodedSymbols.zeros(0, 8)
    res = decode_device(*host_symbols_to_device(sym), nbytes=8)
    assert res.success and not res.overflow and res.items.shape == (0, 2)


# ------------------------------------------- overflow -> host fallback ----
def test_decode_device_overflow_flag():
    sym, _, _ = diff_symbols(20, 17, 2, 128)
    res = decode_device(*host_symbols_to_device(sym), nbytes=8, max_diff=5)
    assert res.overflow and not res.success


def test_peel_backend_device_falls_back_on_overflow():
    sym, _, _ = diff_symbols(20, 17, 2, 128)
    host = peel(sym)
    dev = peel(sym, backend="device", max_diff=5)   # overflow -> host path
    assert dev.success and dev.success == host.success
    assert as_sets(dev.items, dev.sides) == as_sets(host.items, host.sides)


def test_stream_decoder_device_falls_back_on_overflow():
    nbytes = 8
    sym, ai, bi = diff_symbols(12, 9, 2, 96)
    dec = StreamDecoder(nbytes, backend="device", max_diff=4)
    dec.receive(sym)   # raw difference stream (local=None)
    only_a, only_b = dec.result()
    assert dec.decoded
    assert {r.tobytes() for r in only_a} == {r.tobytes() for r in ai}
    assert {r.tobytes() for r in only_b} == {r.tobytes() for r in bi}


# --------------------------------------------- backend plumbing bits ----
def test_resolve_backend():
    assert resolve_backend("host") == "host"
    assert resolve_backend("device") == "device"
    assert resolve_backend("auto") in ("host", "device")
    with pytest.raises(ValueError):
        resolve_backend("gpu")


def test_peel_backend_device_matches_host():
    sym, _, _ = diff_symbols(9, 6, 2, 64)
    host = peel(sym)
    dev = peel(sym, backend="device")
    assert dev.success == host.success
    assert as_sets(dev.items, dev.sides) == as_sets(host.items, host.sides)


def test_stream_decoder_device_incremental_windows():
    """Device-backed incremental decode across many windows == host."""
    nbytes = 8
    sym, ai, bi = diff_symbols(11, 7, 2, 128)
    host_dec = StreamDecoder(nbytes)
    dev_dec = StreamDecoder(nbytes, backend="device")
    for lo in range(0, 128, 16):
        win = sym.window(lo, lo + 16)
        host_done = host_dec.receive(win.copy())
        dev_done = dev_dec.receive(win.copy())
        assert host_done == dev_done
        assert host_dec.decoded == dev_dec.decoded
    assert dev_dec.decoded
    assert host_dec.decoded_at == dev_dec.decoded_at
    ha, hb = host_dec.result()
    da, db = dev_dec.result()
    assert {r.tobytes() for r in ha} == {r.tobytes() for r in da}
    assert {r.tobytes() for r in hb} == {r.tobytes() for r in db}


# --------------------------------------------------- layout round-trip ----
def test_symbols_device_roundtrip_uint64_checks():
    """host -> device -> host preserves the uint64 checksums bit-exactly,
    including values with all four 16-bit quarters populated."""
    rng = np.random.default_rng(3)
    m, L = 64, 3
    sym = CodedSymbols(
        rng.integers(0, 2**32, size=(m, L), dtype=np.uint32),
        rng.integers(0, 2**64, size=m, dtype=np.uint64),
        rng.integers(-3, 4, size=m).astype(np.int64), 4 * L)
    sym.checks[0] = np.uint64(0xFFFFFFFFFFFFFFFF)
    sym.checks[1] = np.uint64(0)
    sym.checks[2] = np.uint64(0x8000000000000001)
    back = device_symbols_to_host(*host_symbols_to_device(sym), 4 * L)
    np.testing.assert_array_equal(back.sums, sym.sums)
    np.testing.assert_array_equal(back.checks, sym.checks)
    np.testing.assert_array_equal(back.counts, sym.counts)
    assert back.sums.flags.writeable
